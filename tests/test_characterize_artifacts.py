"""Edge cases of the dry-run artifact intake (`characterize.terms_from_artifacts`
/ `workloads_from_artifacts`): empty/missing record sets, duplicate family
keys across meshes, and records with missing optional fields."""

import json
import os

import pytest

from repro.core import characterize
from repro.core.engine import PlanningEngine, RooflineTerms, Workload
from repro.core.tpu_power import PEAK_FLOPS_BF16


def _write(dirpath, fname, rec):
    os.makedirs(dirpath, exist_ok=True)
    with open(os.path.join(dirpath, fname), "w") as f:
        json.dump(rec, f)


def _ok_record(flops=1e15, mem=1e12, coll=2e11):
    return {
        "ok": True,
        "hlo": {
            "flops_per_device": flops,
            "memory_bytes_per_device": mem,
            "collective_bytes_per_device": coll,
        },
    }


def test_empty_record_list(tmp_path):
    empty = str(tmp_path / "empty")
    os.makedirs(empty)
    assert characterize.terms_from_artifacts(empty) == {}
    assert characterize.workloads_from_artifacts(empty) == []


def test_missing_directory_is_empty_not_an_error(tmp_path):
    missing = str(tmp_path / "never-created")
    assert characterize.terms_from_artifacts(missing) == {}
    assert characterize.workloads_from_artifacts(missing) == []


def test_duplicate_family_keys_across_meshes_collapse(tmp_path):
    d = str(tmp_path)
    # the same (arch, shape) family dry-run on two meshes: only the
    # requested mesh contributes, so the family appears exactly once
    _write(d, "archa__train_4k__pod.json", _ok_record(flops=1e15))
    _write(d, "archa__train_4k__dcn.json", _ok_record(flops=9e15))
    terms = characterize.terms_from_artifacts(d, mesh="pod")
    assert list(terms) == [("archa", "train_4k")]
    assert terms[("archa", "train_4k")].compute_s == pytest.approx(
        1e15 / PEAK_FLOPS_BF16
    )
    workloads = characterize.workloads_from_artifacts(d, mesh="pod")
    assert len(workloads) == 1
    # intake is deterministic: a second scan yields the same families
    assert [w.key for w in characterize.workloads_from_artifacts(d, mesh="pod")] == [
        w.key for w in workloads
    ]


def test_failed_and_malformed_names_are_skipped(tmp_path):
    d = str(tmp_path)
    _write(d, "archa__train_4k__pod.json", {"ok": False})  # failed dry-run
    _write(d, "not-an-artifact.json", _ok_record())  # name doesn't parse
    _write(d, "archb__train_4k__pod.json", _ok_record())
    assert list(characterize.terms_from_artifacts(d)) == [("archb", "train_4k")]


def test_records_missing_optional_fields(tmp_path):
    d = str(tmp_path)
    # single-device record: no collectives section at all
    _write(
        d,
        "archa__train_4k__pod.json",
        {"ok": True, "hlo": {"flops_per_device": 1e15}},
    )
    # degenerate record: ok but no hlo payload
    _write(d, "archb__train_4k__pod.json", {"ok": True})
    terms = characterize.terms_from_artifacts(d)
    a = terms[("archa", "train_4k")]
    assert a.compute_s == pytest.approx(1e15 / PEAK_FLOPS_BF16)
    assert a.memory_s == 0.0 and a.collective_s == 0.0
    b = terms[("archb", "train_4k")]
    assert (b.compute_s, b.memory_s, b.collective_s) == (0.0, 0.0, 0.0)
    assert b.source == "dryrun"


def test_workloads_keep_unknown_shape_labels_and_plan(tmp_path, fleet_pm):
    d = str(tmp_path)
    _write(d, "archa__train_4k__pod.json", _ok_record())
    _write(d, "archa__exotic_shape__pod.json", _ok_record(flops=3e15))
    workloads = characterize.workloads_from_artifacts(d)
    names = sorted(w.cell.name for w in workloads)
    assert names == ["exotic_shape", "train_4k"]  # stale labels survive
    assert all(isinstance(w, Workload) for w in workloads)
    assert all(isinstance(w.terms, RooflineTerms) for w in workloads)
    # same arch, different shapes: two distinct engine families
    assert len({w.key for w in workloads}) == 2
    engine = PlanningEngine(fleet_pm, noise=0.01, seed=0)
    plans = engine.plan_many(workloads)
    assert len(plans) == 2 and all(p.terms_source == "dryrun" for p in plans)
