"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs;
plus decode-path consistency (prefill + decode == teacher-forced forward)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_smoke_forward_and_train_step(arch_id):
    arch = ARCHS[arch_id]
    cfg = arch.smoke
    params = arch.init(KEY, cfg)
    batch = arch.smoke_batch(seed=1)

    logits = arch.forward(cfg, params, batch)
    assert logits.shape[-1] == cfg.vocab
    assert logits.shape[0] == batch["tokens"].shape[0]
    assert bool(jnp.all(jnp.isfinite(logits)))

    (loss, _), grads = jax.value_and_grad(
        lambda p: arch.loss_fn(cfg, p, batch), has_aux=True
    )(params)
    assert bool(jnp.isfinite(loss))
    gnorm = adamw.global_norm(grads)
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0

    opt = adamw.init(params)
    new_params, new_opt, metrics = adamw.update(
        adamw.AdamWConfig(total_steps=10), params, grads, opt
    )
    assert int(new_opt["step"]) == 1
    # params actually moved
    delta = adamw.global_norm(
        jax.tree_util.tree_map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            new_params,
            params,
        )
    )
    assert float(delta) > 0


@pytest.mark.slow
@pytest.mark.parametrize(
    "arch_id",
    [
        "granite-moe-1b-a400m",
        "granite-20b",
        "qwen1.5-110b",
        "starcoder2-3b",
        "gemma3-12b",
        "phi-3-vision-4.2b",
        "zamba2-7b",
        "whisper-medium",
        "mamba2-130m",
        "phi3.5-moe-42b-a6.6b",
    ],
)
def test_decode_consistency(arch_id):
    """prefill(tokens[:-1]) + decode(tokens[-1]) == forward(tokens)[-1]."""
    arch = ARCHS[arch_id]
    cfg = arch.smoke
    params = arch.init(jax.random.PRNGKey(1), cfg)
    batch = arch.smoke_batch(seed=3, batch=2, seq=16)
    logits_full = arch.forward(cfg, params, batch)

    toks = batch["tokens"]
    pf_batch = {"tokens": toks[:, :-1]}
    if "images" in batch:
        pf_batch["images"] = batch["images"]
    if "frames" in batch:
        pf_batch = {"frames": batch["frames"], "tokens": toks[:, :-1]}
    caches, lg_pre = arch.prefill(cfg, params, pf_batch, max_cache_len=32)
    caches, lg_dec = arch.decode_step(cfg, params, caches, toks[:, -1:])

    err_pre = float(jnp.max(jnp.abs(lg_pre[:, 0] - logits_full[:, -2])))
    err_dec = float(jnp.max(jnp.abs(lg_dec[:, 0] - logits_full[:, -1])))
    # MoE: GShard capacity semantics differ between teacher-forced forward
    # (a token may be dropped when earlier tokens fill its expert's buffer)
    # and single-token decode (capacity never binds) — decode is the *more*
    # faithful routing; allow the capacity-drop delta.
    tol = 8e-2 if arch.family == "moe" else 5e-5
    assert err_pre < tol, f"prefill mismatch {err_pre}"
    assert err_dec < tol, f"decode mismatch {err_dec}"


def test_moe_balance_loss_decreases_with_uniform_routing():
    """load-balance loss is minimal (=1) for uniform expert assignment."""
    from repro.models import moe as moe_mod

    cfg = ARCHS["granite-moe-1b-a400m"].smoke.moe_cfg
    p = moe_mod.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 32, cfg.d_model)), jnp.float32)
    y, aux = moe_mod.forward(p, cfg, x)
    assert y.shape == x.shape
    assert float(aux["load_balance_loss"]) >= 1.0 - 1e-3


def test_vlm_image_tokens_prepended():
    arch = ARCHS["phi-3-vision-4.2b"]
    cfg = arch.smoke
    params = arch.init(KEY, cfg)
    batch = arch.smoke_batch(seed=0, batch=2, seq=8)
    logits = arch.forward(cfg, params, batch)
    assert logits.shape[1] == 8 + cfg.vision.n_patches


def test_input_specs_cover_all_supported_cells():
    from repro.configs.base import SHAPES

    for arch_id, arch in ARCHS.items():
        for shape in SHAPES:
            if not arch.supports(shape):
                assert shape == "long_500k"
                continue
            specs = arch.input_specs(shape)
            assert specs, (arch_id, shape)
            for v in jax.tree_util.tree_leaves(specs):
                assert isinstance(v, jax.ShapeDtypeStruct)


def test_long500k_applicability_matches_design():
    runs = {a for a, arch in ARCHS.items() if arch.supports("long_500k")}
    assert runs == {"gemma3-12b", "zamba2-7b", "mamba2-130m"}
