"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes/dtypes, plus gradient checks for the custom VJPs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.rbf_gram import rbf_gram_pallas

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# rbf_gram
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m,d", [(8, 8, 3), (37, 53, 3), (130, 70, 7), (256, 256, 16)])
@pytest.mark.parametrize("gamma", [0.1, 0.5, 2.0])
def test_rbf_gram_matches_ref(n, m, d, gamma):
    x = jnp.asarray(RNG.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(RNG.normal(size=(m, d)), jnp.float32)
    got = rbf_gram_pallas(x, y, gamma=gamma, interpret=True)
    want = ref.rbf_gram_ref(x, y, gamma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_rbf_gram_batched_matches_ref():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(3, 16, 2)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(3, 20, 2)), jnp.float32)
    got = ops.rbf_gram(x, y, 0.5, impl="pallas_interpret")
    want = ops.rbf_gram(x, y, 0.5, impl="ref")
    assert got.shape == (3, 16, 20)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_rbf_gram_properties():
    x = jnp.asarray(RNG.normal(size=(40, 3)), jnp.float32)
    K = np.asarray(ops.rbf_gram(x, x, 0.5, impl="pallas_interpret"))
    np.testing.assert_allclose(np.diag(K), 1.0, atol=1e-5)  # K(x,x)=1
    np.testing.assert_allclose(K, K.T, atol=1e-5)  # symmetry
    assert (K >= 0).all() and (K <= 1 + 1e-6).all()


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,hk,s,d,causal,window",
    [
        (2, 4, 4, 64, 32, True, None),
        (2, 4, 2, 67, 32, True, None),  # GQA + ragged seq
        (1, 8, 1, 128, 64, True, None),  # MQA
        (2, 4, 2, 80, 32, True, 16),  # sliding window
        (2, 4, 4, 48, 32, False, None),  # bidirectional (encoder)
    ],
)
def test_flash_pallas_vs_naive(b, h, hk, s, d, causal, window, dtype):
    q = jnp.asarray(RNG.normal(size=(b, h, s, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, hk, s, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, hk, s, d)), dtype)
    got = ops.flash_attention(
        q, k, v, causal=causal, window=window, block_q=32, block_k=32,
        impl="pallas_interpret",
    )
    want = ref.mha_naive_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.slow
def test_flash_ref_vs_naive_blocks():
    """Chunked reference across several block sizes (incl. non-dividing)."""
    q = jnp.asarray(RNG.normal(size=(2, 4, 70, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 2, 70, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 2, 70, 16)), jnp.float32)
    want = ref.mha_naive_ref(q, k, v, causal=True)
    for bq, bk in [(16, 16), (32, 16), (70, 70), (128, 128)]:
        got = ref.flash_attention_ref(q, k, v, causal=True, block_q=bq, block_k=bk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("causal,window", [(True, None), (True, 12), (False, None)])
def test_flash_backward_matches_autodiff(causal, window):
    q = jnp.asarray(RNG.normal(size=(2, 6, 50, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 2, 50, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 2, 50, 16)), jnp.float32)

    def f(q, k, v):
        return (
            ops.flash_attention(
                q, k, v, causal=causal, window=window, block_q=16, block_k=16,
                impl="ref",
            )
            ** 2
        ).sum()

    def fn(q, k, v):
        return (ref.mha_naive_ref(q, k, v, causal=causal, window=window) ** 2).sum()

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(fn, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_flash_decode_with_cache_semantics():
    """decode: q at position L attends to cache[:L+1] incl. window."""
    b, h, s, d = 2, 4, 40, 16
    q = jnp.asarray(RNG.normal(size=(b, h, 1, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, h, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, h, s, d)), jnp.float32)
    L = 25
    got = ops.flash_attention(
        q, k, v, causal=False, window=8, q_offset=jnp.asarray(L),
        kv_len=jnp.asarray(L + 1),
    )
    want = ref.mha_naive_ref(
        q, k[:, :, : L + 1], v[:, :, : L + 1], causal=False, window=8, q_offset=L
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------


def _naive_ssd(x, dt, A, B, C):
    b_, s_, h_, p_ = x.shape
    g_, n_ = B.shape[2], B.shape[3]
    rep = h_ // g_
    Bh = np.repeat(np.asarray(B), rep, 2)
    Ch = np.repeat(np.asarray(C), rep, 2)
    hst = np.zeros((b_, h_, n_, p_))
    ys = np.zeros((b_, s_, h_, p_))
    for t in range(s_):
        dec = np.exp(np.asarray(dt)[:, t] * np.asarray(A)[None])
        hst = (
            hst * dec[..., None, None]
            + np.asarray(dt)[:, t, :, None, None]
            * Bh[:, t, :, :, None]
            * np.asarray(x)[:, t, :, None, :]
        )
        ys[:, t] = np.einsum("bhn,bhnp->bhp", Ch[:, t], hst)
    return ys


@pytest.mark.slow
@pytest.mark.parametrize("s,chunk", [(64, 16), (100, 32), (32, 32)])
@pytest.mark.parametrize("g", [1, 2])
def test_ssd_pallas_vs_naive(s, chunk, g):
    b, h, p, n = 2, 4, 8, 16
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, size=(h,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
    got = ops.ssd_scan(x, dt, A, B, C, chunk=chunk, impl="pallas_interpret")
    want = _naive_ssd(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4)
    got_ref = ref.ssd_scan_ref(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got_ref), want, atol=2e-4)


def test_ssd_decode_step_matches_scan():
    b, s, h, p, g, n = 2, 30, 4, 8, 2, 16
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, size=(h,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
    y_scan = ref.ssd_scan_ref(x, dt, A, B, C, chunk=8)
    hstate = jnp.zeros((b, h, n, p))
    outs = []
    for t in range(s):
        hstate, yt = ops.ssm_decode_step(hstate, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        outs.append(yt)
    y_step = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_scan), atol=5e-5)


@pytest.mark.slow
def test_ssd_grad_through_custom_vjp():
    b, s, h, p, g, n = 1, 40, 2, 4, 1, 8
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, size=(h,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
    g1 = jax.grad(lambda x: ops.ssd_scan(x, dt, A, B, C, chunk=8, impl="pallas_interpret").sum())(x)
    g2 = jax.grad(lambda x: ref.ssd_scan_ref(x, dt, A, B, C, chunk=8).sum())(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


# ---------------------------------------------------------------------------
# int8 codec
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [100, 256, 1000, 65536])
def test_int8_roundtrip(n):
    x = jnp.asarray(RNG.normal(size=(n,)) * 3.0, jnp.float32)
    q, s = ops.int8_quantize(x, impl="pallas_interpret")
    xd = ops.int8_dequantize(q, s, n=n, impl="pallas_interpret")
    qr, sr = ref.int8_quantize_ref(x)
    np.testing.assert_array_equal(np.asarray(q)[: qr.shape[0]], np.asarray(qr))
    # error bounded by scale/2 per block
    err = np.abs(np.asarray(xd) - np.asarray(x))
    per_block_bound = np.repeat(np.asarray(sr), 256)[:n] * 0.5 + 1e-7
    assert (err <= per_block_bound).all()


def test_int8_zero_block():
    x = jnp.zeros((512,), jnp.float32)
    q, s = ops.int8_quantize(x, impl="ref")
    xd = ops.int8_dequantize(q, s, n=512, impl="ref")
    assert np.allclose(np.asarray(xd), 0.0)
