"""Substrate tests: data pipeline, checkpoint manager, trainer fault
tolerance (resume equality, preemption), straggler detection, optimizer."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.example_lm import LM_10M
from repro.configs.base import ArchDef
from repro.data.pipeline import PipelineConfig, SyntheticPipeline
from repro.launch import steps as steps_mod
from repro.optim import adamw
from repro.runtime.trainer import StragglerDetector, Trainer

import dataclasses as _dc

TINY = _dc.replace(
    LM_10M,
    n_layers=2,
    d_model=64,
    vocab=512,
    d_ff=128,
    attn=_dc.replace(LM_10M.attn, d_model=64, n_heads=4, n_kv_heads=2, d_head=16),
)
ARCH = ArchDef(arch_id="tiny", family="dense", full=TINY, smoke=TINY, long_500k_ok=False)


def make_pipeline(seed=0, batch=4, seq=32):
    return SyntheticPipeline(PipelineConfig(vocab=TINY.vocab, seq=seq,
                                            global_batch=batch, seed=seed))


def make_step():
    base = jax.jit(
        steps_mod.make_train_step(ARCH, TINY, adamw.AdamWConfig(
            peak_lr=1e-3, warmup_steps=5, total_steps=100)),
        donate_argnums=(0, 1),
    )

    def step(params, opt_state, batch):
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        return base(params, opt_state, jb)

    return step


# ---------------------------------------------------------------------------
# pipeline
# ---------------------------------------------------------------------------


def test_pipeline_deterministic_and_resumable():
    p1 = make_pipeline()
    batches1 = [p1.next() for _ in range(4)]
    p2 = make_pipeline()
    for _ in range(2):
        p2.next()
    state = p2.state_dict()
    p3 = make_pipeline()
    p3.load_state_dict(state)
    b3 = p3.next()
    np.testing.assert_array_equal(b3["tokens"], batches1[2]["tokens"])


def test_pipeline_host_sharding_disjoint():
    cfg = PipelineConfig(vocab=512, seq=16, global_batch=8, seed=0)
    h0 = SyntheticPipeline(cfg, host_id=0, n_hosts=2)
    h1 = SyntheticPipeline(cfg, host_id=1, n_hosts=2)
    b0, b1 = h0.batch_at(0), h1.batch_at(0)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_pipeline_batch_reissue_deterministic():
    # straggler mitigation: any host can regenerate any batch index
    cfg = PipelineConfig(vocab=512, seq=16, global_batch=4, seed=0)
    a = SyntheticPipeline(cfg).batch_at(7)
    b = SyntheticPipeline(cfg).batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    mgr.save(3, tree, {"pipeline": {"step": 3}})
    step, restored = mgr.restore_latest(tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.arange(1000, dtype=jnp.float32)}
    mgr.save_async(10, tree)
    mgr.wait()
    _, restored = mgr.restore_latest(tree)
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(tree["x"]))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        mgr.restore(1, {"x": jnp.zeros((3, 3))})


# ---------------------------------------------------------------------------
# trainer: loss decreases, restart resumes exactly
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("ckpt"))
    params = ARCH.init(jax.random.PRNGKey(0), TINY)
    tr = Trainer(
        train_step=make_step(),
        params=params,
        opt_state=adamw.init(params),
        pipeline=make_pipeline(),
        ckpt_dir=d,
        ckpt_every=10,
    )
    res = tr.run(30, install_signals=False)
    return d, res


@pytest.mark.slow
def test_loss_decreases(trained):
    _, res = trained
    losses = [h["loss"] for h in res["history"]]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


@pytest.mark.slow
def test_restart_resumes_bitwise(trained):
    d, res = trained
    # fresh trainer restores step-30 state and continues; compare against an
    # uninterrupted run to the same step
    params = ARCH.init(jax.random.PRNGKey(0), TINY)
    tr2 = Trainer(
        train_step=make_step(),
        params=params,
        opt_state=adamw.init(params),
        pipeline=make_pipeline(),
        ckpt_dir=d,
        ckpt_every=1000,
    )
    assert tr2.try_restore()
    assert tr2.step == 30
    res2 = tr2.run(35, install_signals=False)

    params_b = ARCH.init(jax.random.PRNGKey(0), TINY)
    tr3 = Trainer(
        train_step=make_step(),
        params=params_b,
        opt_state=adamw.init(params_b),
        pipeline=make_pipeline(),
        ckpt_dir=d + "_fresh",
        ckpt_every=1000,
    )
    res3 = tr3.run(35, install_signals=False)
    l2 = [h["loss"] for h in res2["history"]]
    l3 = [h["loss"] for h in res3["history"] if h["step"] > 30]
    np.testing.assert_allclose(l2, l3, rtol=1e-5)


@pytest.mark.slow
def test_preemption_flag_stops_and_checkpoints(tmp_path):
    params = ARCH.init(jax.random.PRNGKey(0), TINY)
    tr = Trainer(
        train_step=make_step(),
        params=params,
        opt_state=adamw.init(params),
        pipeline=make_pipeline(),
        ckpt_dir=str(tmp_path),
        ckpt_every=1000,
    )
    tr.preempt.requested = True  # simulate SIGTERM
    res = tr.run(50, install_signals=False)
    assert res["exit"] == "preempted"
    assert tr.ckpt.latest_step() == 0  # checkpointed on exit


def test_straggler_detector():
    det = StragglerDetector(n_hosts=4, mad_k=3.0)
    rng = np.random.default_rng(0)
    for _ in range(20):
        for h in range(4):
            t = 1.0 + rng.normal(0, 0.01)
            if h == 2:
                t *= 1.8  # slow host
            det.record(h, t)
    rep = det.report()
    assert 2 in rep.stragglers
    assert rep.stragglers[2] > 1.5
    assert set(rep.stragglers) == {2}


# ---------------------------------------------------------------------------
# optimizer details
# ---------------------------------------------------------------------------


def test_adamw_schedule_shape():
    cfg = adamw.AdamWConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[1] == pytest.approx(1e-3, rel=1e-3)  # end of warmup
    assert lrs[0] < lrs[1]
    assert lrs[-1] == pytest.approx(1e-4, rel=0.05)  # cosine floor


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
