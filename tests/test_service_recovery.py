"""Crash-recovery golden tests: kill the service at every batch index.

The durability contract under test: a ``SchedulerService`` killed after
ANY committed batch and restarted from its journal (fresh process, fresh
scheduler, fresh engine) completes a schedule **bitwise-identical** to
the uninterrupted golden run — same per-job (node, f, cores), same
joules, same refreshes/preemptions/rounds, same total batch count.

Two scenarios split the coverage:

* the **lookahead scenario** (drift + horizon holds): kills land between
  drift observation and refit (telemetry windows must survive the
  journal — the satellite bugfix), and while tentative holds are
  outstanding (recovery restores them as holds for the next reaction to
  re-confirm or release);
* the **migration scenario** (the eager two-node rebalancer from
  ``test_negotiate``): kills land around a preemption, so recovery also
  covers in-flight reservation truncation, stale completion generations
  and carried-prior accounting.

The exhaustive sweeps are ``slow``; a three-index (early/mid/late) fast
variant runs in tier-1 / ``verify.sh --fast``.
"""

import pytest

from repro.core.node_sim import F_MAX, FREQ_GRID, PROFILES
from repro.fleet import (
    FleetNode,
    FleetScheduler,
    Job,
    LookaheadPolicy,
    MigrationPolicy,
    Negotiator,
    NodePool,
    NodeSpec,
    fleet_engine,
    make_pool,
)
from repro.fleet.service import SchedulerService, ServiceKilled

from test_service import (
    QUICK_CORES,
    QUICK_ENGINE_KW,
    QUICK_FREQS,
    fingerprint,
    trace,
)

# -- scenario builders (fresh scheduler per process incarnation) ------------


def _lookahead_scheduler():
    pool = make_pool(3, seed=0)
    engine = fleet_engine(pool, **QUICK_ENGINE_KW)
    return FleetScheduler(
        pool,
        engine,
        char_freqs=QUICK_FREQS[::2],
        char_cores=(1, 8, 16, 32),
        negotiator=Negotiator(pool, engine.power),
        lookahead=LookaheadPolicy(horizon_s=600.0),
    )


def _lookahead_jobs():
    jobs = trace(12, spacing=120.0, slack=2.5)
    drift = [(jobs[0].arrival_s + 1.0, jobs[0].app, 1.7)]
    return jobs, drift


def _migration_scheduler():
    # the eager two-node rebalancer scenario from test_negotiate: drift
    # re-fit preempts an in-flight job off the expensive node
    specs = [
        NodeSpec("good-0"),
        NodeSpec(
            "bad-1",
            static_power_skew=1.5,
            dynamic_power_skew=1.4,
            speed_skew=1.3,
        ),
    ]
    pool = NodePool([FleetNode(s, seed=101 * i) for i, s in enumerate(specs)])
    engine = fleet_engine(pool, **QUICK_ENGINE_KW)
    return FleetScheduler(
        pool,
        engine,
        char_freqs=QUICK_FREQS[::2],
        char_cores=(1, 8, 16, 32),
        migration=MigrationPolicy(
            cost_j=100.0,
            min_drift=0.10,
            min_remaining_frac=0.05,
            min_saving_frac=0.01,
        ),
    )


def _migration_jobs():
    jobs = [
        Job(0, "blackscholes", 3.0, deadline_s=1e6, arrival_s=0.0),
        Job(1, "swaptions", 1.0, deadline_s=1e6, arrival_s=10.0),
        Job(2, "swaptions", 1.0, deadline_s=520.0, arrival_s=20.0),
        Job(3, "swaptions", 1.0, deadline_s=530.0, arrival_s=30.0),
        Job(4, "swaptions", 1.0, deadline_s=540.0, arrival_s=40.0),
    ]
    return jobs, [(15.0, "swaptions", 1.8)]


SCENARIOS = {
    "lookahead": (_lookahead_scheduler, _lookahead_jobs),
    "migration": (_migration_scheduler, _migration_jobs),
}


def _golden(name, tmp_path):
    """The uninterrupted run (with a journal, so batch timing matches the
    killed runs commit-for-commit) + its fingerprint and batch count."""
    build, trace_fn = SCENARIOS[name]
    jobs, drift = trace_fn()
    sched = build()
    service = SchedulerService(sched, journal=str(tmp_path / "golden.json"))
    service.run(jobs, drift_events=drift)
    return service, fingerprint(sched)


def _kill_and_resume(name, tmp_path, k):
    """Kill before batch ``k``, restart from the journal, drain."""
    build, trace_fn = SCENARIOS[name]
    jobs, drift = trace_fn()
    path = str(tmp_path / f"kill-{k}.json")
    sched = build()
    service = SchedulerService(sched, journal=path, kill_after_batches=k)
    with pytest.raises(ServiceKilled):
        service.run(jobs, drift_events=drift)
    fresh = build()  # the restarted process: rebuilt objects, journaled state
    resumed = SchedulerService.resume(path, fresh)
    assert resumed.recovered
    resumed.drain()
    return resumed, fingerprint(fresh)


def _assert_scenario_exercises_its_coverage(name, service, sched):
    if name == "lookahead":
        assert sched.telemetry.refreshes, "drift refit never fired"
        assert sum(r.n_tentative for r in sched.rounds) > 0, (
            "no tentative holds — the lookahead sweep is not covering them"
        )
    else:
        assert sched.telemetry.preemptions, "migration never fired"
        assert any(c.migrations > 0 for c in sched.completed)


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_kill_at_every_batch_index_replays_bitwise(name, tmp_path):
    golden_service, golden_fp = _golden(name, tmp_path)
    _assert_scenario_exercises_its_coverage(
        name, golden_service, golden_service.scheduler
    )
    n = golden_service.n_batches
    assert n > 3, "scenario too small to sweep meaningfully"
    for k in range(n):
        resumed, fp = _kill_and_resume(name, tmp_path, k)
        assert fp == golden_fp, f"kill at batch {k}: schedule diverged"
        assert resumed.n_batches == n, (
            f"kill at batch {k}: resumed run took {resumed.n_batches} "
            f"batches, golden took {n}"
        )


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_kill_early_mid_late_replays_bitwise(name, tmp_path):
    """The fast (tier-1 / verify.sh --fast) slice of the exhaustive
    sweep: genesis commit, mid-run, and the final batch."""
    golden_service, golden_fp = _golden(name, tmp_path)
    _assert_scenario_exercises_its_coverage(
        name, golden_service, golden_service.scheduler
    )
    n = golden_service.n_batches
    for k in (0, n // 2, n - 1):
        resumed, fp = _kill_and_resume(name, tmp_path, k)
        assert fp == golden_fp, f"kill at batch {k}: schedule diverged"
        assert resumed.n_batches == n


def test_recovery_restores_half_detected_drift(tmp_path):
    """The satellite bugfix's regression test: kill BETWEEN the drift
    observation and the refit it will trigger. The detector's sliding
    windows live only in ``TelemetryHub`` — if the journal dropped them
    (the bug), the resumed run would never refresh and the schedule
    would silently diverge from golden."""
    golden_service, golden_fp = _golden("lookahead", tmp_path)
    sched_g = golden_service.scheduler
    assert sched_g.telemetry.refreshes
    t_refresh = sched_g.telemetry.refreshes[0][0]

    build, trace_fn = SCENARIOS["lookahead"]
    jobs, drift = trace_fn()
    path = str(tmp_path / "half-detected.json")
    sched = build()
    # dies on the refresh batch itself: the last commit holds observed
    # errors that have NOT yet triggered the refit
    service = SchedulerService(
        sched, journal=path, kill_at_s=t_refresh - 1e-6
    )
    with pytest.raises(ServiceKilled):
        service.run(jobs, drift_events=drift)

    fresh = build()
    resumed = SchedulerService.resume(path, fresh)
    hub = fresh.telemetry
    assert any(hub.detector._errors.values()), (
        "journal dropped the drift detector's windows — the half-detected "
        "drift was forgotten"
    )
    resumed.drain()
    assert fresh.telemetry.refreshes == sched_g.telemetry.refreshes
    assert fingerprint(fresh) == golden_fp
