"""Horizon-aware fleet (PR 5 tentpole) + the deadline/epsilon bug sweep.

The load-bearing invariants:
  * the reservation ledger is a time-indexed capacity profile over
    half-open ``[start, end)`` intervals — a reservation starting in the
    future is NOT busy now (the latent bug the profile fixes), interval
    queries see everything they overlap, and tentative holds shape
    placement without ever counting as executions;
  * a lookahead round plans ready jobs AND known future arrivals in ONE
    batched ``pareto_many`` pass and is never worse than the myopic round
    (the slot seed's launch-now pass replays the myopic greedy verbatim);
  * a job already past its deadline is planned on the engine's
    fastest-feasible path, not at the leisurely unconstrained optimum;
  * sim-clock comparisons use ONE relative tolerance (``time_eps``), so
    the simulation survives clocks past t = 1e7 s where the seed's
    absolute epsilons underflow the float64 ulp;
  * the engine and baseline-governor simulation loops advance their
    clocks identically — both use ``next_event_time`` output verbatim.
"""

import numpy as np
import pytest

from repro.core.engine import Constraints, Workload
from repro.core.node_sim import F_MAX, FREQ_GRID, PROFILES
from repro.fleet import (
    CapacityProfile,
    FleetNode,
    FleetScheduler,
    Job,
    LookaheadPolicy,
    Negotiator,
    NodePool,
    NodeSpec,
    fleet_engine,
    make_pool,
    time_eps,
)
from repro.fleet import report as report_mod
from repro.fleet import scheduler as scheduler_mod
from repro.fleet.report import run_governor_fleet

QUICK_FREQS = tuple(float(f) for f in FREQ_GRID[::3])
QUICK_CORES = (1, 2, 4, 8, 16, 24, 32)
QUICK_ENGINE_KW = dict(freqs=QUICK_FREQS, cores=QUICK_CORES, noise=0.01, seed=0)


def quick_scheduler(pool=None, **kw):
    pool = pool if pool is not None else make_pool(4, seed=0)
    engine = fleet_engine(pool, **QUICK_ENGINE_KW)
    return FleetScheduler(
        pool,
        engine,
        char_freqs=QUICK_FREQS[::2],
        char_cores=(1, 8, 16, 32),
        **kw,
    )


# ---------------------------------------------------------------------------
# the time-indexed capacity profile (the ledger refactor)
# ---------------------------------------------------------------------------


def test_future_reservation_is_not_busy_now():
    """THE latent bug of the flat ledger: a reservation with a future
    start used to count as busy at ``now``."""
    node = FleetNode(NodeSpec("n", max_cores=32))
    node.reserve(500.0, 600.0, 20, job_id=1)  # starts in the future
    assert node.free_cores(0.0) == 32  # not busy yet (the bug fix)
    assert node.free_cores(500.0) == 12
    assert node.free_cores(599.0) == 12
    assert node.free_cores(600.0) == 32  # half-open: free again at end


def test_interval_queries_see_overlapping_reservations():
    node = FleetNode(NodeSpec("n", max_cores=32))
    node.reserve(100.0, 200.0, 24, job_id=1)
    # instantaneous at 0: free; over [0, 150): the reservation overlaps
    assert node.free_cores(0.0) == 32
    assert node.free_cores(0.0, 150.0) == 8
    assert node.free_cores(0.0, 100.0) == 32  # half-open: touching is free
    assert node.free_cores(200.0, 300.0) == 32
    # min over the interval, not the value at its start
    node.reserve(160.0, 180.0, 8, job_id=2)
    assert node.free_cores(150.0, 300.0) == 0


def test_short_reservations_stay_visible_at_large_clocks():
    """The query tolerance grows with the sim clock (time_eps(1e7) is
    ~0.01 s); it must never swallow a whole segment — a reservation
    shorter than the tolerance still occupies its window, or the ledger
    could double-book a node."""
    prof = CapacityProfile(16)
    t0 = 1.0e7
    prof.add(t0, t0 + 1e-3, 16)  # far shorter than time_eps(1e7)
    assert 1e-3 < time_eps(t0) * 1.0  # the scenario is genuinely sub-eps
    assert prof.busy_at(t0) == 16
    assert prof.free_over(t0, t0 + 1e-3) == 0
    assert prof.earliest_gap(t0, 1e-3, 16) > t0  # must wait, not overlap
    # and a double-booking attempt is caught by the validity check
    prof.add(t0, t0 + 1e-3, 16)
    assert not prof.valid()


def test_capacity_profile_earliest_gap():
    prof = CapacityProfile(32)
    prof.add(0.0, 100.0, 24)
    prof.add(100.0, 300.0, 30)
    # 8 cores fit right away; 16 must wait for t=100's release... which
    # still holds 30, so they wait until t=300
    assert prof.earliest_gap(0.0, 50.0, 8) == 0.0
    assert prof.earliest_gap(0.0, 50.0, 16) == 300.0
    # a window longer than the first idle stretch skips to the next gap
    prof2 = CapacityProfile(32)
    prof2.add(50.0, 100.0, 32)
    assert prof2.earliest_gap(0.0, 40.0, 16) == 0.0
    assert prof2.earliest_gap(0.0, 80.0, 16) == 100.0
    assert prof2.earliest_gap(0.0, 10.0, 64) is None  # exceeds the node


def test_tentative_holds_confirm_release_and_never_complete():
    node = FleetNode(NodeSpec("n", max_cores=32))
    pool = NodePool([node])
    node.reserve(0.0, 100.0, 8, job_id=1)
    node.reserve(50.0, 200.0, 16, job_id=2, tentative=True)
    # holds shape capacity ...
    assert node.free_cores(60.0) == 8
    assert node.free_cores(60.0, include_tentative=False) == 24
    # ... but are never executions: not a completion, not utilization
    assert pool.next_completion(0.0) == pytest.approx(100.0)
    assert pool.next_completion(150.0) is None
    assert node.utilization(100.0) == pytest.approx(800.0 / 3200.0)
    # release drops only tentative holds; confirm promotes them
    assert pool.release_tentative() == 1
    assert node.free_cores(60.0) == 24
    node.reserve(50.0, 200.0, 16, job_id=2, tentative=True)
    assert node.confirm_reservations(2) == 1
    assert pool.next_completion(150.0) == pytest.approx(200.0)
    assert pool.release_tentative() == 0  # nothing tentative left


# ---------------------------------------------------------------------------
# bugfix: past-deadline jobs plan fastest-feasible, not unconstrained
# ---------------------------------------------------------------------------


def test_past_deadline_job_plans_fastest_feasible_point():
    """A job already past its deadline used to get ``max_time_s=None`` —
    the leisurely unconstrained energy optimum. It must instead ride the
    ``on_infeasible="fastest"`` path: the grid's fastest point that still
    honors the core cap."""
    sched = quick_scheduler()
    engine = sched.engine
    late = Job(0, "raytrace", 1.0, deadline_s=-100.0, arrival_s=0.0)
    w = sched._workload(late, now=0.0, free_cap=32)
    assert w.constraints.max_time_s == 0.0  # empty time mask, not None
    plan = engine.plan(w)
    fit = engine._fits[w.key]
    assert plan.step_time_s <= float(fit.T.min()) * (1.0 + 1e-3 + 1e-9)
    # the unconstrained optimum is materially slower — the old behaviour
    relaxed = engine.plan(Workload(arch=w.arch, terms=w.terms))
    assert relaxed.step_time_s > plan.step_time_s * 1.05

    # and the cap survives the fallback: fastest point on <= 8 cores
    w8 = Workload(
        arch=w.arch,
        terms=w.terms,
        constraints=Constraints(max_cores=8, max_time_s=0.0),
    )
    plan8 = engine.plan(w8)
    assert plan8.chips <= 8
    capped = np.where(engine._C <= 8, fit.T, np.inf)
    assert plan8.step_time_s <= float(capped.min()) * (1.0 + 1e-3 + 1e-9)


def test_past_deadline_job_runs_fast_end_to_end():
    """The placement of an already-late job carries the fastest-feasible
    plan's configuration (not the leisurely unconstrained optimum the old
    ``max_time_s=None`` produced)."""
    sched = quick_scheduler()
    late = Job(0, "raytrace", 1.0, deadline_s=1.0, arrival_s=0.0)
    (done,) = sched.run([late])
    engine = sched.engine
    fast_plan = engine.plan(sched._workload(late, now=0.0, free_cap=32))
    relaxed = engine.plan(
        Workload(arch=late.app, terms=sched._terms_key(late))
    )
    assert done.placement.cores == fast_plan.chips
    assert not done.met_deadline  # it was late on arrival; still counted
    # and the fastest plan is genuinely a different, faster configuration
    assert fast_plan.step_time_s < relaxed.step_time_s
    assert done.result.time_s < relaxed.step_time_s * 1.30  # any node skew


# ---------------------------------------------------------------------------
# bugfix: relative time tolerance at large sim clocks
# ---------------------------------------------------------------------------


def test_time_eps_is_relative_and_always_representable():
    for t in (0.0, 1.0, 1e3, 1e7, 1e9, 1e12):
        assert t + time_eps(t) > t  # the comparison can always resolve
    # the seed's absolute epsilons underflow the ulp at large clocks:
    assert 1e7 + 1e-12 == 1e7  # "strictly later" silently degenerated
    assert 1e12 + 1e-6 == 1e12  # even the event clamp underflowed
    assert 1e12 + time_eps(1e12) > 1e12


@pytest.mark.slow
def test_simulation_survives_clocks_past_1e7_seconds():
    """Drive the sim almost four months in: arrivals, deadlines, drift and
    completions all beyond t = 1e7 s must behave exactly like a t = 0
    trace (the seed's absolute epsilons could not tell times apart up
    there)."""
    base = 1.0e7
    apps = sorted(PROFILES)
    offsets = (0.0, 150.0, 300.0, 450.0, 600.0, 750.0)
    jobs = [
        Job(
            i,
            apps[i % len(apps)],
            1.0,
            deadline_s=base + off + PROFILES[apps[i % len(apps)]].time(F_MAX, 16, 1.0) * 3.0,
            arrival_s=base + off,
        )
        for i, off in enumerate(offsets)
    ]
    sched = quick_scheduler()
    completed = sched.run(
        jobs, drift_events=[(base + 200.0, "raytrace", 1.6)]
    )
    assert len(completed) == len(jobs)
    assert all(c.finish_s > base for c in completed)
    assert sched.makespan_s > base
    # the clock genuinely advanced round over round (no stall/no spin)
    nows = [r.now for r in sched.rounds]
    assert all(b > a for a, b in zip(nows, nows[1:]))
    assert len(sched.rounds) < 50  # a stalled eps would burn max_rounds
    # mirror trace at t=0: the large-clock run makes the same decisions
    jobs0 = [
        Job(
            j.job_id, j.app, j.input_size,
            deadline_s=j.deadline_s - base, arrival_s=j.arrival_s - base,
        )
        for j in jobs
    ]
    sched0 = quick_scheduler()
    completed0 = sched0.run(
        jobs0, drift_events=[(200.0, "raytrace", 1.6)]
    )
    cfg = [
        (c.placement.node, c.placement.cores, c.placement.frequency_ghz)
        for c in sorted(completed, key=lambda c: c.placement.job.job_id)
    ]
    cfg0 = [
        (c.placement.node, c.placement.cores, c.placement.frequency_ghz)
        for c in sorted(completed0, key=lambda c: c.placement.job.job_id)
    ]
    assert cfg == cfg0


# ---------------------------------------------------------------------------
# clock-advance parity: one next_event_time, used verbatim by both loops
# ---------------------------------------------------------------------------


def _random_trace(rng, n_jobs):
    apps = sorted(PROFILES)
    jobs, t = [], 0.0
    for i in range(n_jobs):
        app = apps[int(rng.integers(len(apps)))]
        est = PROFILES[app].time(F_MAX, 16, 1.0)
        jobs.append(
            Job(
                i, app, 1.0,
                deadline_s=t + est * float(rng.uniform(1.5, 4.0)),
                arrival_s=t,
            )
        )
        t += float(rng.uniform(0.0, 400.0))
    events = sorted(
        (float(rng.uniform(0.0, t + 1.0)), apps[int(rng.integers(len(apps)))],
         float(rng.uniform(1.1, 1.8)))
        for _ in range(int(rng.integers(1, 3)))
    )
    return jobs, events


@pytest.mark.slow
def test_engine_and_governor_loops_advance_clocks_identically(monkeypatch):
    """Property-style trial sweep: on randomized arrival/drift traces,
    BOTH simulation loops (engine scheduler and baseline-governor FIFO)
    must consume ``next_event_time`` verbatim — every round's ``now`` is
    exactly the previous call's return, strictly increasing, with drift
    events applied by the shared ``apply_due_events`` before each round.
    This pins the drift-event ordering the one-definition docstring
    promises."""
    orig = scheduler_mod.next_event_time  # the ONE definition, pre-patch
    assert report_mod.next_event_time is orig  # both loops bind it
    sink = {"calls": []}

    def recording(pool, pending, events, ei, now):
        out = orig(pool, pending, events, ei, now)
        sink["calls"].append((now, out))
        return out

    monkeypatch.setattr(scheduler_mod, "next_event_time", recording)
    monkeypatch.setattr(report_mod, "next_event_time", recording)

    rng = np.random.default_rng(1234)
    for trial in range(3):
        jobs, events = _random_trace(rng, n_jobs=int(rng.integers(3, 6)))

        sink["calls"] = eng_calls = []
        sched = quick_scheduler(pool=make_pool(3, seed=trial))
        sched.run(jobs, drift_events=events)

        sink["calls"] = gov_calls = []
        run_governor_fleet(
            make_pool(3, seed=trial), jobs, "performance",
            drift_events=events,
        )

        for calls in (eng_calls, gov_calls):
            assert calls, "the loop must consult next_event_time"
            # first round fires at t=0
            assert calls[0][0] == 0.0
            for (now_a, out_a), (now_b, _) in zip(calls, calls[1:]):
                # the next round's clock IS the previous return, bitwise
                assert now_b == out_a
                assert now_b > now_a  # and strictly advances
            # the final call ended the loop: nothing left, or unplaceable
            last_out = calls[-1][1]
            assert last_out is None or last_out > calls[-1][0]
        # both loops saw the identical event list (same objects, no
        # reordering): events due at a round's now are applied fleet-wide
        # by apply_due_events before the round plans — shared by both.
        assert events == sorted(events)


# ---------------------------------------------------------------------------
# the horizon-aware rounds
# ---------------------------------------------------------------------------


def _stranding_trace():
    """Two long loose-deadline jobs arrive first; a tight 4-job burst is
    known to arrive at t=120. A myopic round strands the cheap fast nodes
    on the long jobs; the horizon sees the burst coming."""
    jobs = [
        Job(0, "fluidanimate", 3.0, deadline_s=30000.0, arrival_s=0.0),
        Job(1, "fluidanimate", 3.0, deadline_s=30000.0, arrival_s=0.0),
    ]
    burst_t = 120.0
    est = PROFILES["raytrace"].time(F_MAX, 16, 2.0)
    for i in range(2, 6):
        jobs.append(
            Job(i, "raytrace", 2.0, deadline_s=burst_t + est * 1.35,
                arrival_s=burst_t)
        )
    return jobs


def _run_mode(jobs, *, lookahead, negotiate=True, horizon_s=600.0):
    pool = make_pool(4, seed=0)
    engine = fleet_engine(pool, **QUICK_ENGINE_KW)
    sched = FleetScheduler(
        pool,
        engine,
        negotiator=Negotiator(pool, engine.power) if negotiate else None,
        lookahead=LookaheadPolicy(horizon_s=horizon_s) if lookahead else None,
    )
    completed = sched.run(jobs)
    return sched, completed


def test_lookahead_beats_myopic_on_the_stranding_trace():
    """The ISSUE acceptance in miniature: on a bursty trace the lookahead
    fleet spends <= the myopic fleet's joules at equal-or-fewer misses —
    and on THIS trace the win is strict (the myopic round gives the cheap
    nodes away just before the burst needs them)."""
    jobs = _stranding_trace()
    myopic, _ = _run_mode(jobs, lookahead=False)
    look, _ = _run_mode(jobs, lookahead=True)
    assert look.deadline_misses() <= myopic.deadline_misses()
    assert look.total_energy_j() <= myopic.total_energy_j() * 1.001
    # the strict win that motivates the whole subsystem
    assert look.total_energy_j() < myopic.total_energy_j()
    assert look.deadline_misses() < myopic.deadline_misses()
    assert look.telemetry.n_tentative_reservations > 0
    # holds are plans: none survive the simulation
    assert all(
        not r.tentative for n in look.pool for r in n.reservations
    )


def test_lookahead_round_is_one_pareto_many_over_ready_and_future():
    """The single-batched-pass invariant extends to the horizon: a
    lookahead planning round issues exactly ONE ``pareto_many`` covering
    every ready job AND every known future arrival — never a separate
    ``plan_many``."""
    pool = make_pool(4, seed=0)
    engine = fleet_engine(pool, **QUICK_ENGINE_KW)
    sched = FleetScheduler(
        pool,
        engine,
        negotiator=Negotiator(pool, engine.power),
        lookahead=LookaheadPolicy(horizon_s=600.0),
    )
    plan_batches, pareto_batches = [], []
    orig_plan, orig_pareto = engine.plan_many, engine.pareto_many

    def counting_plan_many(ws):
        ws = list(ws)
        plan_batches.append(len(ws))
        return orig_plan(ws)

    def counting_pareto_many(ws):
        ws = list(ws)
        pareto_batches.append(len(ws))
        return orig_pareto(ws)

    engine.plan_many = counting_plan_many
    engine.pareto_many = counting_pareto_many
    sched.run(_stranding_trace())
    planned = [r for r in sched.rounds if r.planned]
    assert plan_batches == []
    assert pareto_batches == [r.n_pending + r.n_future for r in planned]
    assert any(r.n_future > 0 for r in planned)  # the burst was foreseen
    assert any(r.n_tentative > 0 for r in planned)
    assert len(sched.completed) == 6


def test_lookahead_without_negotiator_also_not_worse():
    """The greedy (non-negotiated) scheduler gets the same horizon: the
    slot seed alone must never be worse than the myopic greedy."""
    jobs = _stranding_trace()
    myopic, _ = _run_mode(jobs, lookahead=False, negotiate=False)
    look, _ = _run_mode(jobs, lookahead=True, negotiate=False)
    assert look.deadline_misses() <= myopic.deadline_misses()
    assert look.total_energy_j() <= myopic.total_energy_j() * 1.001
    # rounds never count as negotiated without a configured Negotiator
    assert not any(r.negotiated for r in look.rounds)


def test_slot_mode_matches_scalar_negotiation_on_an_idle_pool():
    """With no future jobs and an idle pool the slot mode IS the scalar
    mode: same assignments, every start slot at ``now``."""
    pool = make_pool(3, seed=0)
    engine = fleet_engine(pool, **QUICK_ENGINE_KW)
    neg = Negotiator(pool, engine.power)
    jobs = [
        Job(i, app, 1.0, deadline_s=3000.0 + 100.0 * i, arrival_s=0.0)
        for i, app in enumerate(sorted(PROFILES))
    ]
    sched = FleetScheduler(pool, engine)
    workloads = [sched._workload(j, 0.0, 32) for j in jobs]
    frontiers = engine.pareto_many(workloads)
    terms = [w.terms for w in workloads]
    slacks = [j.deadline_s for j in jobs]
    free = [n.free_cores(0.0) for n in pool]
    scalar = neg.negotiate(jobs, terms, frontiers, free, slacks)
    slotted = neg.negotiate(
        jobs, terms, frontiers, free, slacks,
        now=0.0, arrivals=[0.0] * len(jobs),
        profiles=[n.capacity_profile() for n in pool],
    )
    for a, b in zip(scalar.assignments, slotted.assignments):
        assert (a is None) == (b is None)
        if a is not None:
            assert (a.point_idx, a.node_idx, a.cores) == (
                b.point_idx, b.node_idx, b.cores
            )
            assert b.start_s == 0.0


def test_engine_earliest_start_shifts_the_slack():
    """``Workload.earliest_start_s`` measures a future job's slack from
    its arrival: the shifted workload's frontier equals the frontier of
    the explicitly tightened constraint."""
    pool = make_pool(2, seed=0)
    engine = fleet_engine(pool, **QUICK_ENGINE_KW)
    terms = scheduler_mod.family_key("raytrace", 1.0)
    base = Workload(
        arch="raytrace", terms=terms,
        constraints=Constraints(max_time_s=2000.0),
    )
    shifted = Workload(
        arch="raytrace", terms=terms,
        constraints=Constraints(max_time_s=2000.0),
        earliest_start_s=1500.0,
    )
    tightened = Workload(
        arch="raytrace", terms=terms,
        constraints=Constraints(max_time_s=500.0),
    )
    assert engine.pareto(shifted) == engine.pareto(tightened)
    assert engine.pareto(shifted) != engine.pareto(base)
    p_shift, p_tight = engine.plan_many([shifted, tightened])
    assert (p_shift.frequency_ghz, p_shift.chips) == (
        p_tight.frequency_ghz, p_tight.chips
    )
    # a fully-blown window (delay >= slack) rides the fastest path
    blown = Workload(
        arch="raytrace", terms=terms,
        constraints=Constraints(max_time_s=2000.0),
        earliest_start_s=2500.0,
    )
    fit = engine._fits[blown.key]
    assert engine.plan(blown).step_time_s <= float(fit.T.min()) * (1.0 + 2e-3)
