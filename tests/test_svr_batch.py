"""Batched SVR fitting: fit vs fit_many parity (ragged batches, ISTA
polish), predict_each, and determinism of kfold_cv / grid_search."""

import numpy as np
import pytest

from repro.core import svr
from repro.core.engine import solve_grid

ENGINE_KW = dict(gamma=0.5, standardize=True, log_target=True, eps=1e-4)


def _toy_set(rng, n, scale=1.0):
    x = np.stack(
        [rng.uniform(0.6, 1.1, n),
         rng.choice([16.0, 32.0, 64.0, 128.0, 256.0, 512.0], n)], 1
    ).astype(np.float32)
    t = scale * (0.01 / x[:, 0]) * (256.0 / x[:, 1]) + 0.002 * scale
    y = np.maximum(t * (1 + rng.normal(0, 0.02, n)), 1e-6).astype(np.float32)
    return x, y


# ---------------------------------------------------------------------------
# fit vs fit_many parity
# ---------------------------------------------------------------------------


def test_fit_many_matches_fit_same_shape():
    rng = np.random.default_rng(0)
    sets = [_toy_set(rng, 48, scale=i + 1) for i in range(4)]
    batched = svr.fit_many(sets, **ENGINE_KW)
    for (x, y), mb in zip(sets, batched):
        ms = svr.fit(x, y, **ENGINE_KW)
        np.testing.assert_allclose(
            np.asarray(mb.beta), np.asarray(ms.beta), rtol=1e-5, atol=1e-7
        )
        assert mb.bias == pytest.approx(ms.bias, abs=1e-9)
        assert (mb.y_mean, mb.y_std) == (ms.y_mean, ms.y_std)


def test_fit_many_matches_fit_ragged():
    """Padding with masked rows must not leak into any item's solution."""
    rng = np.random.default_rng(1)
    sets = [_toy_set(rng, n, scale=i + 1) for i, n in enumerate((24, 48, 36))]
    batched = svr.fit_many(sets, **ENGINE_KW)
    for (x, y), mb in zip(sets, batched):
        ms = svr.fit(x, y, **ENGINE_KW)
        assert np.asarray(mb.beta).shape == np.asarray(ms.beta).shape
        np.testing.assert_allclose(
            np.asarray(mb.beta), np.asarray(ms.beta), rtol=1e-5, atol=1e-7
        )
        assert mb.bias == pytest.approx(ms.bias, abs=1e-6)
        # predictions agree on a fresh query grid
        xq = _toy_set(rng, 17)[0]
        np.testing.assert_allclose(
            np.asarray(svr.predict(mb, xq)),
            np.asarray(svr.predict(ms, xq)),
            rtol=1e-4,
        )


@pytest.mark.slow  # two extra (B, n) jit compiles of the vmapped ISTA pass
def test_fit_many_ista_polish_parity():
    rng = np.random.default_rng(2)
    sets = [_toy_set(rng, n) for n in (20, 32)]
    kw = dict(ENGINE_KW, iters=50)
    batched = svr.fit_many(sets, **kw)
    for (x, y), mb in zip(sets, batched):
        ms = svr.fit(x, y, **kw)
        np.testing.assert_allclose(
            np.asarray(mb.beta), np.asarray(ms.beta), rtol=1e-4, atol=1e-6
        )
        assert mb.bias == pytest.approx(ms.bias, abs=1e-4)


def test_fit_many_chosen_configs_match_fit():
    """The contract that matters downstream: identical (f, p) argmin picks."""
    rng = np.random.default_rng(3)
    sets = [_toy_set(rng, 66, scale=i + 1) for i in range(3)]
    batched = svr.fit_many(sets, **ENGINE_KW)
    F, P = np.meshgrid(
        np.round(np.arange(0.6, 1.101, 0.05), 3), (16, 32, 64, 128, 256, 512),
        indexing="ij",
    )
    grid = np.stack([F.ravel(), P.ravel()], 1).astype(np.float32)
    W = 100.0 + P * F**3
    for (x, y), mb in zip(sets, batched):
        ms = svr.fit(x, y, **ENGINE_KW)
        Tb = np.asarray(svr.predict(mb, grid)).reshape(F.shape)
        Ts = np.asarray(svr.predict(ms, grid)).reshape(F.shape)
        assert solve_grid(F, P, Tb, W) == solve_grid(F, P, Ts, W)


def test_fit_many_accepts_characterizations(blackscholes_ch):
    """Duck-typing: Characterization objects go straight into fit_many."""
    from repro.core.characterize import subsample

    chs = [subsample(blackscholes_ch, 0.2, seed=s) for s in (0, 1)]
    models = svr.fit_many(chs)
    assert len(models) == 2
    for ch, m in zip(chs, models):
        assert svr.pae(m, ch.features, ch.times) < 0.10


def test_fit_many_empty():
    assert svr.fit_many([]) == []


# ---------------------------------------------------------------------------
# predict_each
# ---------------------------------------------------------------------------


def test_predict_each_matches_predict():
    rng = np.random.default_rng(4)
    sets = [_toy_set(rng, 32, scale=i + 1) for i in range(3)]
    models = svr.fit_many(sets, **ENGINE_KW)
    queries = [s[0] for s in sets]
    batched = svr.predict_each(models, queries)
    for m, q, b in zip(models, queries, batched):
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(svr.predict(m, q)), rtol=1e-5, atol=1e-6
        )


def test_predict_each_heterogeneous_fallback():
    rng = np.random.default_rng(5)
    a = svr.fit(*_toy_set(rng, 20), **ENGINE_KW)
    b = svr.fit(*_toy_set(rng, 28), **ENGINE_KW)
    queries = [_toy_set(rng, 7)[0], _toy_set(rng, 9)[0]]
    out = svr.predict_each([a, b], queries)
    np.testing.assert_allclose(
        np.asarray(out[0]), np.asarray(svr.predict(a, queries[0])), rtol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(out[1]), np.asarray(svr.predict(b, queries[1])), rtol=1e-6
    )


# ---------------------------------------------------------------------------
# determinism (paper §3.4 reproducibility): same seed -> same folds -> same
# CV metrics and same grid-search winner
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_xy():
    rng = np.random.default_rng(7)
    x = np.stack(
        [rng.uniform(1.2, 2.2, 60), rng.integers(1, 33, 60).astype(float),
         rng.choice([1.0, 3.0, 5.0], 60)], 1
    ).astype(np.float32)
    y = (
        300.0 * x[:, 2] ** 0.9 * (0.1 + 0.9 / x[:, 1]) * (0.8 / x[:, 0] + 0.2)
        * (1 + rng.normal(0, 0.01, 60))
    ).astype(np.float32)
    return x, y


def test_kfold_cv_deterministic_under_seed(small_xy):
    x, y = small_xy
    a = svr.kfold_cv(x, y, k=4, seed=0)
    b = svr.kfold_cv(x, y, k=4, seed=0)
    assert a == b
    c = svr.kfold_cv(x, y, k=4, seed=1)  # different folds, still finite
    assert np.isfinite(c).all()


def test_grid_search_deterministic_under_seed(small_xy):
    x, y = small_xy
    kw = dict(Cs=(1e2, 10e3), gammas=(0.5, 1.0), k=3)
    a = svr.grid_search(x, y, **kw)
    b = svr.grid_search(x, y, **kw)
    assert a == b
    assert a["C"] in (1e2, 10e3) and a["gamma"] in (0.5, 1.0)
    assert np.isfinite(a["pae"])  # accuracy on this tiny raw set is not the
    # point — identical fold splits and an identical winner are
