"""Beyond-paper: energy-optimal (chips, frequency) plans for LM workloads.

The paper's pipeline applied to the TPU fleet, now through the canonical
``core.engine.PlanningEngine``: fit the fleet power model from telemetry,
characterize each workload family's step-time surface once (memoized SVR on
the dry-run roofline sampler), evaluate every grid in one batched pass, and
minimize E = P×T. Reports each plan, the saving vs the race-to-idle
max-slice baseline, and the one-shot ``plan_many`` wall time.
"""

from __future__ import annotations

from benchmarks.common import emit, save_json, timed
from repro.configs.base import SHAPES
from repro.core.engine import PlanningEngine, Workload
from repro.core.tpu_power import FleetTelemetry, fit_fleet_power

WORKLOADS = [
    ("qwen1.5-110b", "train_4k"),
    ("phi3.5-moe-42b-a6.6b", "train_4k"),
    ("gemma3-12b", "prefill_32k"),
    ("gemma3-12b", "decode_32k"),
    ("starcoder2-3b", "train_4k"),
    ("zamba2-7b", "long_500k"),
    ("mamba2-130m", "train_4k"),
]


def run():
    pm = fit_fleet_power(FleetTelemetry(seed=0))
    emit(
        "tpu_power_fit",
        0.0,
        f"c=({pm.c1:.1f};{pm.c2:.1f};{pm.c3:.0f};{pm.c4:.0f})"
        f"_race_to_idle_512chips={pm.race_to_idle_expected(1.1, 512, 2)}",
    )
    engine = PlanningEngine(pm, noise=0.01, seed=0)
    requests = [Workload(arch_id, SHAPES[shape]) for arch_id, shape in WORKLOADS]
    plans, us = timed(engine.plan_many, requests)
    out = {}
    for (arch_id, shape), plan in zip(WORKLOADS, plans):
        save = 100 * (plan.baseline_energy_j - plan.energy_per_step_j) / max(
            plan.baseline_energy_j, 1e-12
        )
        emit(
            f"tpu_plan_{arch_id}_{shape}",
            us / len(plans),
            f"{plan.chips}chips@{plan.frequency_ghz:.2f}GHz_"
            f"{plan.step_time_s*1e3:.1f}ms_{plan.power_w/1e3:.1f}kW_"
            f"save={save:.1f}%_src={plan.terms_source}",
        )
        out[f"{arch_id}/{shape}"] = plan.__dict__
    emit("tpu_plan_many_total", us, f"n={len(plans)}_batched=1")
    save_json("tpu_planner", out)
    return out
