"""Shared benchmark plumbing: timing + CSV emission + result registry."""

from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")

# every save_json of the current process, keyed by bench name — the
# trajectory appender (``run.py --append-trajectory``) snapshots this so a
# run's results land in ONE dated trajectory entry instead of N files read
# back from disk
RUN_RESULTS: dict = {}


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6


def save_json(name: str, payload):
    RUN_RESULTS[name] = payload
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def append_trajectory(results: dict, *, quick: bool, path: str = None) -> str:
    """Append one run's bench results to the perf trajectory.

    ``experiments/bench/trajectory.json`` is a JSON list, one entry per
    benchmark run: ``{"run_at": iso-utc, "quick": bool, "results":
    {bench name: that bench's saved payload}}`` — the run-over-run record
    the per-bench files (always overwritten in place) cannot provide.
    Returns the trajectory path.
    """
    path = path or os.path.join(RESULTS_DIR, "trajectory.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    trajectory = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                trajectory = json.load(f)
            if not isinstance(trajectory, list):
                raise ValueError("trajectory must be a JSON list")
        except ValueError:
            # a previously interrupted (or hand-mangled) write must not
            # brick the record: keep the evidence aside, start fresh
            os.replace(path, path + ".corrupt")
            trajectory = []
    trajectory.append(
        {
            "run_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "quick": bool(quick),
            "results": dict(results),
        }
    )
    # atomic append: a kill mid-dump may lose THIS entry, never the history
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(trajectory, f, indent=1, default=float)
    os.replace(tmp, path)
    return path
