"""Shared benchmark plumbing: timing + CSV emission + result registry."""

from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6


def save_json(name: str, payload):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)
