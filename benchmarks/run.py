"""Benchmark entry point: ``python -m benchmarks.run [--quick]``.

One section per paper table/figure (bench_paper_repro), plus the roofline
table from the dry-run artifacts, the TPU planner (beyond-paper), and kernel
micro-benches. Prints ``name,us_per_call,derived`` CSV lines.
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--quick", action="store_true", help="reduced characterization grids"
    )
    ap.add_argument(
        "--only",
        choices=[
            "paper", "roofline", "planner", "engine", "kernels", "svr_fit",
            "fleet",
        ],
        default=None,
    )
    args = ap.parse_args()

    print("name,us_per_call,derived")

    if args.only in (None, "kernels"):
        from benchmarks import bench_kernels

        bench_kernels.run()
    if args.only in (None, "paper"):
        from benchmarks import bench_paper_repro

        bench_paper_repro.run(full=not args.quick)
    if args.only in (None, "roofline"):
        from benchmarks import bench_roofline

        bench_roofline.run()
        # right-sizing study needs its own process (512 virtual devices)
        import subprocess
        import sys as _sys

        proc = subprocess.run(
            [_sys.executable, "-m", "benchmarks.bench_rightsize"],
            capture_output=True,
            text=True,
            timeout=1200,
        )
        print(proc.stdout, end="")
    if args.only in (None, "planner"):
        from benchmarks import bench_tpu_planner

        bench_tpu_planner.run()
    if args.only in (None, "engine"):
        from benchmarks import bench_engine

        bench_engine.run()
    if args.only in (None, "svr_fit"):
        from benchmarks import bench_svr_fit

        bench_svr_fit.run()
    if args.only in (None, "fleet"):
        from benchmarks import bench_fleet

        bench_fleet.run()


if __name__ == "__main__":
    main()
