"""Benchmark entry point: ``python -m benchmarks.run [--quick] [--only NAME]``.

One section per paper table/figure (bench_paper_repro), plus the roofline
table from the dry-run artifacts, the TPU planner (beyond-paper), the
batched engine / SVR-fit / fleet rounds, and kernel micro-benches. Prints
``name,us_per_call,derived`` CSV lines; most sections also persist a JSON
record under ``experiments/bench/`` (schema: ``docs/benchmarks.md``).

Benchmarks self-register in ``BENCHES`` — the ``--only`` choices, the
dispatch and the unknown-name error all derive from that one registry, so
a new benchmark cannot be half-wired (listed but silently never run, or
runnable but unlisted).
"""

from __future__ import annotations

import argparse
from typing import Optional, Sequence


def _run_kernels(quick: bool) -> None:
    from benchmarks import bench_kernels

    bench_kernels.run()


def _run_paper(quick: bool) -> None:
    from benchmarks import bench_paper_repro

    bench_paper_repro.run(full=not quick)


def _run_roofline(quick: bool) -> None:
    from benchmarks import bench_roofline

    bench_roofline.run()
    # right-sizing study needs its own process (512 virtual devices)
    import subprocess
    import sys as _sys

    proc = subprocess.run(
        [_sys.executable, "-m", "benchmarks.bench_rightsize"],
        capture_output=True,
        text=True,
        timeout=1200,
    )
    print(proc.stdout, end="")


def _run_planner(quick: bool) -> None:
    from benchmarks import bench_tpu_planner

    bench_tpu_planner.run()


def _run_bench_tpu(quick: bool) -> None:
    from benchmarks import bench_tpu

    bench_tpu.run()


def _run_engine(quick: bool) -> None:
    from benchmarks import bench_engine

    bench_engine.run()


def _run_engine_scale(quick: bool) -> None:
    from benchmarks import bench_engine

    bench_engine.run_scale(quick=quick)


def _run_svr_fit(quick: bool) -> None:
    from benchmarks import bench_svr_fit

    bench_svr_fit.run()


def _run_fleet(quick: bool) -> None:
    from benchmarks import bench_fleet

    bench_fleet.run()


def _run_analysis(quick: bool) -> None:
    from benchmarks import bench_analysis

    bench_analysis.run(quick=quick)


def _run_obs(quick: bool) -> None:
    from benchmarks import bench_obs

    bench_obs.run()


def _run_service(quick: bool) -> None:
    from benchmarks import bench_service

    bench_service.run()


# name -> runner; insertion order is execution order for a full run
BENCHES = {
    "kernels": _run_kernels,
    "paper": _run_paper,
    "roofline": _run_roofline,
    "planner": _run_planner,
    "bench_tpu": _run_bench_tpu,
    "engine": _run_engine,
    "engine_scale": _run_engine_scale,
    "svr_fit": _run_svr_fit,
    "fleet": _run_fleet,
    "analysis": _run_analysis,
    "obs": _run_obs,
    "service": _run_service,
}


def run_selected(
    only: Optional[str] = None,
    *,
    quick: bool = False,
    append_trajectory: bool = False,
) -> None:
    """Run one benchmark (or all). Unknown names fail loudly with the
    valid-name list — never a silent no-op run. ``append_trajectory``
    appends the run's saved payloads as one dated entry to
    ``experiments/bench/trajectory.json`` (the run-over-run perf record;
    the per-bench JSON files are overwritten in place and keep no
    history)."""
    if only is not None and only not in BENCHES:
        raise SystemExit(
            f"unknown benchmark {only!r}; valid names: {', '.join(BENCHES)}"
        )
    from benchmarks import common

    common.RUN_RESULTS.clear()
    print("name,us_per_call,derived")
    for name, runner in BENCHES.items():
        if only in (None, name):
            runner(quick)
    if append_trajectory:
        path = common.append_trajectory(common.RUN_RESULTS, quick=quick)
        print(f"trajectory: appended {len(common.RUN_RESULTS)} result(s) to {path}")


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--quick", action="store_true", help="reduced characterization grids"
    )
    # free-form on purpose: run_selected owns the validation so the error
    # (with the valid-name list) is identical for CLI and programmatic use
    ap.add_argument(
        "--only",
        metavar="NAME",
        choices=None,
        default=None,
        help=f"run one benchmark: {', '.join(BENCHES)}",
    )
    ap.add_argument(
        "--append-trajectory",
        action="store_true",
        help="append this run's results to experiments/bench/trajectory.json "
        "(run-over-run perf record)",
    )
    args = ap.parse_args(argv)
    run_selected(
        args.only, quick=args.quick, append_trajectory=args.append_trajectory
    )


if __name__ == "__main__":
    main()
