"""Event-driven service overhead: what does the bus + reaction loop cost?

The service contract has a perf half: ``SchedulerService`` replays the
lockstep schedule bitwise, and it must do so without materially slowing
the simulation — the event bus, batch dispatch, completion streaming and
generation bookkeeping all ride between reactions, so their cost is pure
overhead on top of the same ``step()`` calls the lockstep driver makes.

Measurement: full end-to-end runs (the overhead is per *batch*, so a
single round cannot see it) of the bench_fleet trace with staggered
arrivals + one drift event, lockstep ``run()`` vs ``SchedulerService``
(no journal), on one shared warm engine. Samples interleave (a one-sided
A…A B…B split bakes slow container drift into the ratio), each arm's
floor is the mean of its quietest third, and the reported ratio is the
quietest of the independent phases — overhead is a constant offset and
noise only adds, so the min-over-phases converges on the true ratio from
above while a genuinely over-budget service fails every phase.

* ``overhead_ratio`` — service run / lockstep run. Budget: ≤ 1.15,
  enforced as an ABSOLUTE ceiling by ``scripts/check_trajectory.py``
  (a design contract, not a trajectory trend).
* ``journal_overhead_ratio`` — informational: the same run with a
  journal (one atomic full-state snapshot per batch), over the
  journal-less service run. Durability is opt-in, so this is recorded
  but not gated.

Parity is asserted before timing: a fast schedule that diverges from
the lockstep one is not an optimization, it is a different simulator.
"""

from __future__ import annotations

import os
import tempfile
import time

from benchmarks.bench_fleet import CORES, FREQS, N_NODES, _jobs
from benchmarks.common import emit, save_json
from repro.fleet import FleetScheduler, Negotiator, fleet_engine, make_pool
from repro.fleet.service import SchedulerService

N_JOBS = 16  # full runs, not single rounds: keep one sample sub-second
SPACING_S = 150.0
REPS = 3  # independent measurement phases; the ratio keeps the quietest
SAMPLES = 6  # interleaved lockstep/service samples per phase
DRIFT = [(SPACING_S * N_JOBS / 3, "raytrace", 1.6)]


def _trace():
    """The bench_fleet jobs, staggered so the run has real event flow
    (arrivals interleave with completions instead of one t=0 burst)."""
    import dataclasses

    jobs = []
    for j in _jobs()[:N_JOBS]:
        t = j.job_id * SPACING_S
        jobs.append(
            dataclasses.replace(j, arrival_s=t, deadline_s=j.deadline_s + t)
        )
    return jobs


def _fingerprint(sched):
    return [
        (
            c.placement.job.job_id,
            c.placement.node,
            c.placement.frequency_ghz,
            c.placement.cores,
            c.total_energy_j,
            c.finish_s,
        )
        for c in sched.completed
    ]


def run():
    engine_kw = dict(freqs=FREQS, cores=CORES, noise=0.01, seed=0)
    eng = fleet_engine(make_pool(N_NODES, seed=0), **engine_kw)
    jobs = _trace()

    def _scheduler():
        pool = make_pool(N_NODES, seed=0)
        return FleetScheduler(pool, eng, negotiator=Negotiator(pool, eng.power))

    def _lockstep():
        sched = _scheduler()
        sched.run(jobs, drift_events=DRIFT)
        return sched

    def _service(journal=None):
        sched = _scheduler()
        SchedulerService(sched, journal=journal).run(jobs, drift_events=DRIFT)
        return sched

    # parity gate + warmup in one: both paths run once before any timing
    golden = _fingerprint(_lockstep())
    assert _fingerprint(_service()) == golden, (
        "service schedule diverged from lockstep — fix parity before "
        "measuring overhead"
    )

    def _sample(fn):
        t0 = time.perf_counter()
        fn()
        return (time.perf_counter() - t0) * 1e6

    def _phase():
        lock, svc = [], []
        for _ in range(SAMPLES):
            lock.append(_sample(_lockstep))
            svc.append(_sample(_service))
        k = max(SAMPLES // 3, 1)
        return (sum(sorted(lock)[:k]) / k, sum(sorted(svc)[:k]) / k)

    phases = [_phase() for _ in range(REPS)]
    lockstep_us, service_us = min(phases, key=lambda p: p[1] / p[0])
    overhead_ratio = service_us / lockstep_us

    # journal cost (informational): one timed run per arm is enough for
    # an order-of-magnitude record — durability is opt-in, not gated
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "journal.json")
        journaled_us = _sample(lambda: _service(journal=path))
    journal_overhead_ratio = journaled_us / service_us

    emit(
        "service_run",
        service_us,
        f"nodes={N_NODES}_jobs={N_JOBS}_lockstep_us={lockstep_us:.0f}_"
        f"ratio={overhead_ratio:.3f}x",
    )
    emit(
        "service_journaled_run",
        journaled_us,
        f"journal_ratio={journal_overhead_ratio:.2f}x",
    )
    save_json(
        "service",
        {
            "n_nodes": N_NODES,
            "n_jobs": N_JOBS,
            "phases": REPS,
            "samples_per_phase": SAMPLES,
            "lockstep_run_us": lockstep_us,
            "service_run_us": service_us,
            "overhead_ratio": overhead_ratio,
            "journaled_run_us": journaled_us,
            "journal_overhead_ratio": journal_overhead_ratio,
        },
    )
    return overhead_ratio


if __name__ == "__main__":
    # PYTHONPATH=src python -m benchmarks.bench_service
    print("name,us_per_call,derived")
    run()
