"""Flight-recorder overhead: what does watching the scheduler cost?

The obs contract has a perf half: instrumentation hooks ride the fleet
round's hot path (`fleet.round` span, engine/negotiator sub-spans,
counters, staleness gauges), so they must be near-free when recording
and *actually* free when not. Two measurements on the warm negotiated
scheduling round from bench_fleet (4 nodes / 32 jobs, family fits and
jit pre-paid):

* ``overhead_ratio`` — recorded round / unrecorded round. A single
  round has ±30% container jitter, which swamps a percent-level
  contract, so the measurement is layered: each timed sample batches 5
  rounds, off/on samples interleave (a one-sided A…A B…B split would
  bake slow drift into the ratio), each arm's floor is the mean of its
  quietest samples, and the reported ratio is the quietest of 5
  independent phases — overhead is a constant offset and noise only
  adds, so the min-over-phases converges on the true ratio from above
  while a genuinely over-budget recorder fails every phase. Budget:
  ≤ 1.03 — recording costs at most 3% of a round.
* ``null_overhead_ratio`` — the disabled path, bounded from a
  microbenchmark: ns per null hook bundle (span enter/exit + counter +
  histogram + instant event against the installed null singletons) ×
  the hook volume of one recorded round, as a fraction of the round.
  Budget: ≤ 1.005 — the default-off hooks cost under 0.5%.

Both ratios are enforced as ABSOLUTE ceilings by
``scripts/check_trajectory.py`` (not median-of-history trends: the
budget is a design contract, not a trajectory), so instrumentation
creep on the round path fails ``scripts/verify.sh``.
"""

from __future__ import annotations

import time

from benchmarks.bench_fleet import CORES, FREQS, N_JOBS, N_NODES, _jobs
from benchmarks.common import emit, save_json, timed
from repro import obs
from repro.fleet import FleetScheduler, Negotiator, fleet_engine, make_pool

REPS = 5  # independent measurement phases; the ratio keeps the quietest
SAMPLES = 12  # interleaved off/on samples per phase
ROUNDS_PER_SAMPLE = 5  # batch rounds so one sample outlasts timer jitter
NULL_ITERS = 50_000


def _null_hook_bundle():
    """One round-ish unit of instrumentation against the null singletons."""
    with obs.span("fleet.round", cat="fleet", sim_t_s=0.0):
        obs.counter("fleet.rounds").inc()
        obs.histogram("fleet.round.pending_jobs").observe(32)
        obs.event("fleet.drift", cat="fleet")


def run():
    pool = make_pool(N_NODES, seed=0)
    engine_kw = dict(freqs=FREQS, cores=CORES, noise=0.01, seed=0)
    eng = fleet_engine(pool, **engine_kw)
    jobs = _jobs()

    # pre-pay family fits + the B=32 tensor compile (steady-state rounds
    # run warm; the bench measures the round, not a cold characterization)
    warm_sched = FleetScheduler(make_pool(N_NODES, seed=0), eng)
    eng.pareto_many([warm_sched._workload(j, 0.0, max(CORES)) for j in jobs])

    def _round():
        rpool = make_pool(N_NODES, seed=0)
        sched = FleetScheduler(
            rpool, eng, negotiator=Negotiator(rpool, eng.power)
        )
        sched._pending = sorted(jobs, key=lambda j: (j.arrival_s, j.job_id))
        return sched

    # one throwaway recorded round so both arms start fully warm
    with obs.recording():
        _round().step(0.0)

    def _sample(recorded):
        """Per-round time over a batch of rounds (schedulers prebuilt):
        one ~40 ms sample averages the ±30% single-round jitter."""
        scheds = [_round() for _ in range(ROUNDS_PER_SAMPLE)]
        if recorded:
            with obs.recording():
                t0 = time.perf_counter()
                for s in scheds:
                    s.step(0.0)
                dt = time.perf_counter() - t0
        else:
            t0 = time.perf_counter()
            for s in scheds:
                s.step(0.0)
            dt = time.perf_counter() - t0
        return dt / ROUNDS_PER_SAMPLE * 1e6

    def _phase():
        """One measurement phase: interleaved off/on samples, each arm's
        floor as the mean of its quietest third (a plain min is itself a
        noisy order statistic)."""
        off, on = [], []
        for _ in range(SAMPLES):
            off.append(_sample(recorded=False))
            on.append(_sample(recorded=True))
        k = SAMPLES // 3
        return (
            sum(sorted(off)[:k]) / k,
            sum(sorted(on)[:k]) / k,
        )

    # the overhead is a constant offset and container noise only ADDS:
    # the min over independent phases converges on the true ratio from
    # above, while a genuinely over-budget recorder still fails every
    # phase — so keep the quietest phase's ratio
    phases = [_phase() for _ in range(REPS)]
    disabled_us, enabled_us = min(phases, key=lambda p: p[1] / p[0])
    overhead_ratio = enabled_us / disabled_us

    # hook volume of one round: recorded events are a faithful count of
    # span/instant hook firings; counters/gauges fire fewer times than
    # events, so 2x events is a generous bundle count for the bound
    with obs.recording() as rec:
        _round().step(0.0)
    n_hook_bundles = 2 * len(rec.trace)

    _null_hook_bundle()  # warm
    t0 = time.perf_counter()
    for _ in range(NULL_ITERS):
        _null_hook_bundle()
    null_hook_ns = (time.perf_counter() - t0) / NULL_ITERS * 1e9
    null_overhead_ratio = 1.0 + (null_hook_ns * n_hook_bundles) / (
        disabled_us * 1e3
    )

    emit(
        "obs_round_recorded",
        enabled_us,
        f"nodes={N_NODES}_jobs={N_JOBS}_disabled_us={disabled_us:.0f}_"
        f"ratio={overhead_ratio:.3f}x_events={len(rec.trace)}",
    )
    emit(
        "obs_null_hooks",
        null_hook_ns / 1e3,
        f"per_bundle_ns={null_hook_ns:.0f}_bundles_per_round="
        f"{n_hook_bundles}_ratio={null_overhead_ratio:.4f}x",
    )
    save_json(
        "obs",
        {
            "n_nodes": N_NODES,
            "n_jobs": N_JOBS,
            "phases": REPS,
            "samples_per_phase": SAMPLES,
            "rounds_per_sample": ROUNDS_PER_SAMPLE,
            "disabled_round_us": disabled_us,
            "enabled_round_us": enabled_us,
            "overhead_ratio": overhead_ratio,
            "null_hook_ns": null_hook_ns,
            "hook_bundles_per_round": n_hook_bundles,
            "null_overhead_ratio": null_overhead_ratio,
            "trace_events_per_round": len(rec.trace),
        },
    )
    return overhead_ratio


if __name__ == "__main__":
    # PYTHONPATH=src python -m benchmarks.bench_obs
    print("name,us_per_call,derived")
    run()
