"""Kernel micro-benchmarks (wall time of the REFERENCE path on CPU — the
Pallas kernels target TPU and are validated in interpret mode; these numbers
track the jnp fallback and the SVR end-to-end fit cost)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import svr
from repro.kernels import ops


def run():
    rng = np.random.default_rng(0)

    # rbf_gram: the paper-technique hotspot at characterization scale
    x = jnp.asarray(rng.normal(size=(1760, 3)), jnp.float32)
    K, us = timed(lambda: jax.block_until_ready(ops.rbf_gram(x, x, 0.5, impl="ref")))
    emit("rbf_gram_1760x1760", us, f"gbytes={K.size*4/1e9:.3f}")

    # SVR end-to-end fit on a paper-sized grid
    fs = np.arange(1.2, 2.3, 0.1)
    ps = np.arange(1, 33)
    Ns = np.array([1, 2, 3, 4, 5])
    F, P, N = np.meshgrid(fs, ps, Ns, indexing="ij")
    T = (60 * N + 120) / (F / 2.2) / (1.0 / (0.15 + 0.85 / P))
    xf = np.stack([F.ravel(), P.ravel(), N.ravel()], 1)
    y = T.ravel()
    m, us = timed(svr.fit, xf, y)
    emit("svr_fit_1760", us, f"train_pae={svr.pae(m, xf, y):.4f}")

    # flash attention reference (the dry-run compute path)
    q = jnp.asarray(rng.normal(size=(1, 8, 2048, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 2048, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 2048, 64)), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, impl="ref"))
    jax.block_until_ready(f(q, k, v))  # compile
    out, us = timed(lambda: jax.block_until_ready(f(q, k, v)))
    flops = 4 * 8 * 2048 * 2048 * 64
    emit("flash_ref_2048", us, f"gflops={flops/us/1e3:.1f}")

    # ssd scan reference
    b, s, h, p, n = 1, 2048, 8, 64, 64
    xs = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2, (h,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, 1, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, 1, n)), jnp.float32)
    g = jax.jit(lambda *a: ops.ssd_scan(*a, chunk=128, impl="ref"))
    jax.block_until_ready(g(xs, dt, A, B, C))
    out, us = timed(lambda: jax.block_until_ready(g(xs, dt, A, B, C)))
    emit("ssd_ref_2048", us, f"chunk=128")

    # int8 codec
    big = jnp.asarray(rng.normal(size=(1 << 20,)), jnp.float32)
    fq = jax.jit(lambda x: ops.int8_quantize(x, impl="ref"))
    jax.block_until_ready(fq(big))
    (_, _), us = timed(lambda: jax.block_until_ready(fq(big)))
    emit("int8_quant_1M", us, f"gbps={big.size*4/us/1e3:.2f}")
