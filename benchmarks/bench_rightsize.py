"""§Perf cell 3 (mamba2-130m train_4k — worst roofline fraction): the fix is
not a kernel change but the PAPER'S OWN TECHNIQUE — right-sizing the slice.

A 130M-param model on 256 chips is communication/memory-dominated: per-chip
compute shrinks 1/c while the DP gradient all-reduce stays ~2·params·dtype
per chip. This bench lowers the same cell on successively smaller
data-parallel slices and reports the roofline terms + the planner's
energy-optimal choice, tying the roofline table to the paper's thesis.

Run inside the dry-run device context:
    python -m benchmarks.bench_rightsize
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

import json  # noqa: E402

import jax  # noqa: E402

from benchmarks.common import emit, save_json  # noqa: E402
from repro.configs import get_arch  # noqa: E402
from repro.configs.base import SHAPES  # noqa: E402
from repro.core.tpu_power import HBM_BW, ICI_BW, PEAK_FLOPS_BF16  # noqa: E402
from repro.launch import hlo_analysis, steps  # noqa: E402
from repro.launch.dryrun import TRAIN_ACCUM  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.optim import adamw  # noqa: E402


def lower_on(arch_id: str, chips: int):
    arch = get_arch(arch_id)
    cfg = arch.full
    cell = SHAPES["train_4k"]
    mesh = make_mesh((chips, 1), ("data", "model"))
    specs = arch.input_specs("train_4k")
    with mesh, steps.activation_policy(arch, cell, mesh):
        params_abs, opt_abs = steps.abstract_train_state(arch, cfg)
        pshard, oshard, bshard = steps.train_shardings(
            arch, cfg, mesh, cell, params_abs, opt_abs, specs
        )
        fn = steps.make_train_step(
            arch, cfg, adamw.AdamWConfig(), zero_shardings=oshard["m"],
            accum=TRAIN_ACCUM.get(arch_id, 1),
        )
        compiled = (
            jax.jit(
                fn,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1),
            )
            .lower(params_abs, opt_abs, specs)
            .compile()
        )
    counts = hlo_analysis.analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "chips": chips,
        "compute_s": counts.flops / PEAK_FLOPS_BF16,
        "memory_s": counts.memory_bytes / HBM_BW,
        "collective_s": counts.collective_bytes / ICI_BW,
        "temp_gb": mem.temp_size_in_bytes / 2**30,
        "collectives": counts.collectives,
    }


def run(arch_id: str = "mamba2-130m"):
    rows = []
    for chips in (256, 128, 64, 32, 16):
        r = lower_on(arch_id, chips)
        t = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / t
        rows.append({**r, "step_time_s": t, "roofline_fraction": frac})
        emit(
            f"rightsize_{arch_id}_{chips}chips",
            0.0,
            f"comp={r['compute_s']:.3f}s_mem={r['memory_s']:.3f}s_"
            f"coll={r['collective_s']:.4f}s_frac={frac:.3f}",
        )
    # chip-seconds per step ~ energy proxy: fewer chips wins until compute-bound
    best = min(rows, key=lambda r: r["chips"] * r["step_time_s"])
    emit(
        f"rightsize_{arch_id}_best",
        0.0,
        f"{best['chips']}chips_frac={best['roofline_fraction']:.3f}"
        f"_chipseconds={best['chips']*best['step_time_s']:.1f}",
    )
    save_json(f"rightsize_{arch_id}", rows)
    return rows


if __name__ == "__main__":
    run()
