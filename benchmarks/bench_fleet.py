"""Fleet scheduling-round throughput: ONE batched ``plan_many`` over every
pending job vs per-job sequential planning (the pre-fleet loop: one full
characterization + grid predict per job).

The scenario the scheduler faces every round: a 4-node heterogeneous pool
and 32 pending (app, input, deadline) jobs drawn from 8 workload families.
The batched round pays one ``svr.fit_many`` for all cache-missing families
and one grid prediction + objective tensor for all jobs; the sequential
path re-characterizes per job. Acceptance: ≥3× on the 4-node / 32-job
round, with identical chosen (f, p) configurations — and the negotiation
round's ``pareto_many`` (every job's frontier from the shared tensor)
adds <10% to the batched round time, with per-job ``pareto`` parity.
"""

from __future__ import annotations

from benchmarks.common import emit, save_json, timed
from repro.core.node_sim import F_MAX, FREQ_GRID, PROFILES
from repro.fleet import FleetScheduler, Job, fleet_engine, make_pool

N_JOBS = 32
N_NODES = 4
FREQS = tuple(float(f) for f in FREQ_GRID[::2])
CORES = tuple(range(1, 33, 2))


def _jobs():
    """32 pending jobs over 4 apps × 2 inputs = 8 characterization families."""
    apps = sorted(PROFILES)
    jobs = []
    for i in range(N_JOBS):
        app = apps[i % len(apps)]
        n = (1.0, 3.0)[(i // len(apps)) % 2]
        est = PROFILES[app].time(F_MAX, 16, n)
        jobs.append(
            Job(i, app, n, deadline_s=est * (2.0 + 0.25 * (i % 5)), arrival_s=0.0)
        )
    return jobs


def run():
    pool = make_pool(N_NODES, seed=0)
    engine_kw = dict(freqs=FREQS, cores=CORES, noise=0.01, seed=0)
    base = fleet_engine(pool, **engine_kw)
    pm = base.power  # one reference power fit shared by all engines

    jobs = _jobs()
    sched = FleetScheduler(pool, base)
    workloads = [sched._workload(j, 0.0, max(CORES)) for j in jobs]
    n_families = len({w.key for w in workloads})

    # warm the jit caches outside the timed region (the objective tensor
    # compiles once per batch size: warm both B=32 and B=1)
    warm = fleet_engine(pool, power_model=pm, **engine_kw)
    warm.plan_many(workloads)
    warm.clear_cache(analytic=False)
    warm.plan(workloads[0])

    seq_eng = fleet_engine(pool, power_model=pm, **engine_kw)

    def sequential():
        plans = []
        for w in workloads:
            # the pre-fleet loop re-characterized (re-fit) per job; fleet
            # workloads carry explicit AppTerms so no analytic memo at play
            seq_eng.clear_cache(analytic=False)
            plans.append(seq_eng.plan(w))
        return plans

    seq_plans, seq_us = timed(sequential)

    batch_eng = fleet_engine(pool, power_model=pm, **engine_kw)
    batch_plans, batch_us = timed(batch_eng.plan_many, workloads)

    seq_cfg = [(p.frequency_ghz, p.chips) for p in seq_plans]
    batch_cfg = [(p.frequency_ghz, p.chips) for p in batch_plans]
    assert seq_cfg == batch_cfg, "batched round diverges from sequential plans"

    # the negotiation add-on: every pending job's frontier from the warm
    # engine (fits + grid predictions cached by plan_many — exactly the
    # scheduler's round shape). Acceptance: < 10% on top of the batched
    # round.
    frontiers, pareto_us = timed(batch_eng.pareto_many, workloads)
    single = [batch_eng.pareto(w) for w in workloads]
    assert frontiers == single, "pareto_many diverges from per-job pareto"
    pareto_overhead = pareto_us / batch_us

    speedup = seq_us / batch_us
    emit(
        "fleet_round_plan_many",
        batch_us,
        f"nodes={N_NODES}_jobs={N_JOBS}_families={n_families}_"
        f"seq_us={seq_us:.0f}_speedup={speedup:.1f}x_parity=ok",
    )
    emit(
        "fleet_round_pareto_many",
        pareto_us,
        f"jobs={N_JOBS}_overhead={100 * pareto_overhead:.1f}%_of_round_"
        f"parity=ok",
    )
    save_json(
        "fleet",
        {
            "n_nodes": N_NODES,
            "n_jobs": N_JOBS,
            "n_families": n_families,
            "sequential_us": seq_us,
            "batched_us": batch_us,
            "speedup": speedup,
            "pareto_many_us": pareto_us,
            "pareto_overhead_frac": pareto_overhead,
            "plans": [
                {"app": p.arch, "f_ghz": p.frequency_ghz, "cores": p.chips,
                 "energy_j": p.energy_per_step_j}
                for p in batch_plans
            ],
        },
    )
    return speedup


if __name__ == "__main__":
    # PYTHONPATH=src python -m benchmarks.bench_fleet
    print("name,us_per_call,derived")
    run()
