"""Fleet scheduling-round throughput: ONE batched ``plan_many`` over every
pending job vs per-job sequential planning (the pre-fleet loop: one full
characterization + grid predict per job).

The scenario the scheduler faces every round: a 4-node heterogeneous pool
and 32 pending (app, input, deadline) jobs drawn from 8 workload families.
The batched round pays one ``svr.fit_many`` for all cache-missing families
and one grid prediction + objective tensor for all jobs; the sequential
path re-characterizes per job. Acceptance: ≥3× on the 4-node / 32-job
round, with identical chosen (f, p) configurations — and the negotiation
round's ``pareto_many`` (every job's frontier from the shared tensor)
adds <10% to the batched round time, with per-job ``pareto`` parity.

The horizon add-on: one full HORIZON-AWARE scheduling round vs the
myopic negotiated round at the IDENTICAL planning load — the myopic
round sees all 32 jobs as ready, the lookahead round sees the same 32 as
24 ready + 8 known future arrivals (slot-mode joint assignment +
tentative reservations). Equal load isolates what the horizon machinery
costs (start-slot axis, interval capacity queries, holds) from what the
horizon *does* (planning future jobs is the feature, not overhead).
Acceptance: the lookahead round stays within 1.5× the myopic round.
Both rounds are timed warm (family fits + jit pre-paid — steady-state
rounds reuse the characterization cache) and as a median of 5 fresh
schedulers (a single ~20 ms sample is hostage to scheduler jitter).
"""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit, save_json, timed
from repro.core.node_sim import F_MAX, FREQ_GRID, PROFILES
from repro.fleet import (
    FleetScheduler,
    Job,
    LookaheadPolicy,
    Negotiator,
    fleet_engine,
    make_pool,
)

N_JOBS = 32
N_NODES = 4
N_FUTURE = 8  # trailing jobs arrive inside the lookahead horizon
HORIZON_S = 1200.0
FREQS = tuple(float(f) for f in FREQ_GRID[::2])
CORES = tuple(range(1, 33, 2))


def _jobs():
    """32 pending jobs over 4 apps × 2 inputs = 8 characterization families."""
    apps = sorted(PROFILES)
    jobs = []
    for i in range(N_JOBS):
        app = apps[i % len(apps)]
        n = (1.0, 3.0)[(i // len(apps)) % 2]
        est = PROFILES[app].time(F_MAX, 16, n)
        jobs.append(
            Job(i, app, n, deadline_s=est * (2.0 + 0.25 * (i % 5)), arrival_s=0.0)
        )
    return jobs


def _bursty_jobs():
    """The lookahead-round trace: the same 32 jobs, but the last
    ``N_FUTURE`` arrive as a known future burst inside the horizon."""
    jobs = []
    burst_t = HORIZON_S / 2
    for j in _jobs():
        if j.job_id >= N_JOBS - N_FUTURE:
            j = dataclasses.replace(
                j, arrival_s=burst_t, deadline_s=j.deadline_s + burst_t
            )
        jobs.append(j)
    return jobs


def run():
    pool = make_pool(N_NODES, seed=0)
    engine_kw = dict(freqs=FREQS, cores=CORES, noise=0.01, seed=0)
    base = fleet_engine(pool, **engine_kw)
    pm = base.power  # one reference power fit shared by all engines

    jobs = _jobs()
    sched = FleetScheduler(pool, base)
    workloads = [sched._workload(j, 0.0, max(CORES)) for j in jobs]
    n_families = len({w.key for w in workloads})

    # warm the jit caches outside the timed region (the objective tensor
    # compiles once per batch size: warm both B=32 and B=1)
    warm = fleet_engine(pool, power_model=pm, **engine_kw)
    warm.plan_many(workloads)
    warm.pareto_many(workloads)  # the fused pareto callable compiles once
    # per (B, nf, nc) geometry; steady-state rounds run warm
    warm.clear_cache(analytic=False)
    warm.plan(workloads[0])

    seq_eng = fleet_engine(pool, power_model=pm, **engine_kw)

    def sequential():
        plans = []
        for w in workloads:
            # the pre-fleet loop re-characterized (re-fit) per job; fleet
            # workloads carry explicit AppTerms so no analytic memo at play
            seq_eng.clear_cache(analytic=False)
            plans.append(seq_eng.plan(w))
        return plans

    seq_plans, seq_us = timed(sequential)

    batch_eng = fleet_engine(pool, power_model=pm, **engine_kw)
    batch_plans, batch_us = timed(batch_eng.plan_many, workloads)

    seq_cfg = [(p.frequency_ghz, p.chips) for p in seq_plans]
    batch_cfg = [(p.frequency_ghz, p.chips) for p in batch_plans]
    assert seq_cfg == batch_cfg, "batched round diverges from sequential plans"

    # the negotiation add-on: every pending job's frontier from the warm
    # engine (fits + grid predictions cached by plan_many — exactly the
    # scheduler's round shape). Acceptance: < 10% on top of the batched
    # round.
    frontiers, pareto_us = timed(batch_eng.pareto_many, workloads)
    single = [batch_eng.pareto(w) for w in workloads]
    assert frontiers == single, "pareto_many diverges from per-job pareto"
    pareto_overhead = pareto_us / batch_us

    speedup = seq_us / batch_us

    # the horizon add-on: equal 32-job planning load — the myopic round
    # plans the whole trace as ready, the lookahead round plans the same
    # trace as 24 ready + 8 known-future (slot options, interval ledger,
    # tentative holds). Both warm: B = 32 is the shared tensor shape.
    bursty = _bursty_jobs()
    # ONE engine for every trial: it is pool-independent here (explicit
    # grids, shared power model) and steady-state rounds reuse the
    # characterization cache anyway — pre-pay the 8 family fits + the
    # B = 32 tensor once instead of once per trial, so the timed step
    # measures the round, not a cold fit
    round_eng = fleet_engine(
        make_pool(N_NODES, seed=0), power_model=pm, **engine_kw
    )
    warm_sched = FleetScheduler(make_pool(N_NODES, seed=0), round_eng)
    round_eng.pareto_many(
        [warm_sched._workload(j, 0.0, max(CORES)) for j in jobs]
    )

    def _round(lookahead):
        rpool = make_pool(N_NODES, seed=0)
        sched = FleetScheduler(
            rpool,
            round_eng,
            negotiator=Negotiator(rpool, round_eng.power),
            lookahead=(
                LookaheadPolicy(horizon_s=HORIZON_S) if lookahead else None
            ),
        )
        trace = bursty if lookahead else jobs  # same 32 workloads
        sched._pending = sorted(trace, key=lambda j: (j.arrival_s, j.job_id))
        return sched

    def _median_round(lookahead, trials=5):
        """step() consumes its scheduler, so each trial builds a fresh one
        (fits pre-paid outside the timing); the median absorbs the
        scheduler jitter a single ~20 ms sample is hostage to."""
        times, log = [], None
        for _ in range(trials):
            sched = _round(lookahead)
            log, us = timed(sched.step, 0.0)
            times.append(us)
        return log, sorted(times)[len(times) // 2]

    myopic_log, myopic_us = _median_round(lookahead=False)
    look_log, look_us = _median_round(lookahead=True)
    assert myopic_log.n_pending == N_JOBS
    assert look_log.n_pending == N_JOBS - N_FUTURE
    assert look_log.n_pending + look_log.n_future == N_JOBS  # equal load
    lookahead_overhead = look_us / myopic_us

    emit(
        "fleet_round_plan_many",
        batch_us,
        f"nodes={N_NODES}_jobs={N_JOBS}_families={n_families}_"
        f"seq_us={seq_us:.0f}_speedup={speedup:.1f}x_parity=ok",
    )
    emit(
        "fleet_round_pareto_many",
        pareto_us,
        f"jobs={N_JOBS}_overhead={100 * pareto_overhead:.1f}%_of_round_"
        f"parity=ok",
    )
    emit(
        "fleet_round_lookahead",
        look_us,
        f"jobs={N_JOBS}_as_ready={N_JOBS - N_FUTURE}+future={N_FUTURE}_"
        f"myopic32_us={myopic_us:.0f}_ratio={lookahead_overhead:.2f}x_"
        f"tentative={look_log.n_tentative}",
    )
    save_json(
        "fleet",
        {
            "n_nodes": N_NODES,
            "n_jobs": N_JOBS,
            "n_families": n_families,
            "sequential_us": seq_us,
            "batched_us": batch_us,
            "speedup": speedup,
            "pareto_many_us": pareto_us,
            "pareto_overhead_frac": pareto_overhead,
            "myopic_round_us": myopic_us,
            "lookahead_round_us": look_us,
            "lookahead_overhead_ratio": lookahead_overhead,
            "lookahead_tentative": look_log.n_tentative,
            "plans": [
                {"app": p.arch, "f_ghz": p.frequency_ghz, "cores": p.chips,
                 "energy_j": p.energy_per_step_j}
                for p in batch_plans
            ],
        },
    )
    return speedup


if __name__ == "__main__":
    # PYTHONPATH=src python -m benchmarks.bench_fleet
    print("name,us_per_call,derived")
    run()
