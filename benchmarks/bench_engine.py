"""plan_many throughput: the batched PlanningEngine vs the sequential seed
path (one full characterization + Gram-predict per plan).

The realistic fleet scenario: a scheduler plans many workload *variants*
(objectives, deadlines, step budgets) drawn from a handful of workload
families. The seed path paid a full SVR fit per plan; the engine pays one
fit per family (memoized) and pushes every pending grid through one batched
``rbf_gram`` call. Acceptance: ≥3× on ≥8 workloads, with identical chosen
configurations.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.configs.base import SHAPES
from repro.core import svr
from repro.core.engine import Constraints, PlanningEngine, Workload
from repro.core.tpu_power import FleetTelemetry, fit_fleet_power

FAMILIES = [
    ("qwen1.5-110b", "train_4k"),
    ("gemma3-12b", "prefill_32k"),
    ("starcoder2-3b", "train_4k"),
    ("mamba2-130m", "train_4k"),
]


def _workloads():
    """16 planning requests over 4 characterization families."""
    out = []
    for arch, shape in FAMILIES:
        cell = SHAPES[shape]
        out.append(Workload(arch, cell))
        out.append(Workload(arch, cell, objective="edp"))
        out.append(Workload(arch, cell, n_steps=1000, objective="ed2p"))
        out.append(
            Workload(arch, cell, constraints=Constraints(max_frequency_ghz=0.95))
        )
    return out


def run():
    pm = fit_fleet_power(FleetTelemetry(seed=0))
    workloads = _workloads()

    # warm up jit caches outside the timed region — the batched objective
    # tensor compiles per batch size, so warm both the B=16 and B=1 shapes
    warm = PlanningEngine(pm, noise=0.01, seed=0)
    warm.plan_many(workloads)
    warm.clear_cache(analytic=False)
    warm.plan(workloads[0])

    seq_eng = PlanningEngine(pm, noise=0.01, seed=0)

    def sequential():
        plans = []
        for w in workloads:
            # the seed path re-characterized (re-FIT) every plan but kept
            # the analytic-terms memo; clearing it too would time
            # jax.eval_shape re-traces instead of fit/predict cost
            seq_eng.clear_cache(analytic=False)
            plans.append(seq_eng.plan(w))
        return plans

    seq_plans, seq_us = timed(sequential)

    batch_eng = PlanningEngine(pm, noise=0.01, seed=0)
    batch_plans, batch_us = timed(batch_eng.plan_many, workloads)

    seq_cfg = [(p.frequency_ghz, p.chips) for p in seq_plans]
    batch_cfg = [(p.frequency_ghz, p.chips) for p in batch_plans]
    assert seq_cfg == batch_cfg, "batched plans diverge from sequential plans"

    speedup = seq_us / batch_us
    emit(
        "engine_plan_many",
        batch_us,
        f"n={len(workloads)}_families={len(FAMILIES)}_"
        f"seq_us={seq_us:.0f}_speedup={speedup:.1f}x_parity=ok",
    )
    save_json(
        "engine",
        {
            "n_workloads": len(workloads),
            "n_families": len(FAMILIES),
            "sequential_us": seq_us,
            "batched_us": batch_us,
            "speedup": speedup,
            "plans": [p.__dict__ for p in batch_plans],
        },
    )
    return speedup


def run_scale(quick: bool = False):
    """PR-7 scale sweep: the fused Pallas grid argmin vs the exact batched
    path at B ∈ {32, 1k, 10k} pending workloads, plus RFF fit timing at
    n ∈ {64, 512, 4096} training samples.

    The exact arm (``plan_many(fused=False)``) is the pre-PR-7 batched
    pipeline — one device objective tensor, then a host argmin + mask
    build per workload; the fused arm reduces the whole (B, G) sweep in
    one kernel call. Parity is asserted at EVERY size: the fused arm must
    reproduce the exact arm's chosen (f, cores) bitwise. The RFF rows
    check the linear-in-n promise of ``svr.fit_many(method="rff")``:
    ``rff_linearity`` is (time ratio)/(n ratio) across the sweep — ~1 is
    linear, n²-ish growth pushes it toward n_max/n_min.
    """
    pm = fit_fleet_power(FleetTelemetry(seed=0))
    eng = PlanningEngine(pm, noise=0.01, seed=0)
    base = _workloads()

    sizes = (32, 256) if quick else (32, 1024, 10000)
    plan_rows = []
    for b in sizes:
        ws = [base[i % len(base)] for i in range(b)]
        # warm both arms: family fits + grid predictions memoize, and the
        # fused kernel compiles once per (B, nf, nc) geometry
        eng.plan_many(ws, fused=False)
        eng.plan_many(ws)
        exact_plans, exact_us = timed(eng.plan_many, ws, fused=False)
        fused_plans, fused_us = timed(eng.plan_many, ws)
        assert [(p.frequency_ghz, p.chips) for p in exact_plans] == [
            (p.frequency_ghz, p.chips) for p in fused_plans
        ], f"fused plans diverge from exact plans at B={b}"
        speedup = exact_us / fused_us
        emit(
            "engine_scale_plan",
            fused_us,
            f"B={b}_exact_us={exact_us:.0f}_speedup={speedup:.1f}x_parity=ok",
        )
        plan_rows.append(
            {
                "n_workloads": b,
                "exact_us": exact_us,
                "fused_us": fused_us,
                "speedup": speedup,
            }
        )

    rff_ns = (64, 512) if quick else (64, 512, 4096)
    rng = np.random.default_rng(0)
    rff_rows = []
    for n in rff_ns:
        x = np.stack(
            [rng.uniform(0.6, 1.1, n), rng.choice([8.0, 64.0, 256.0, 512.0], n)],
            axis=1,
        ).astype(np.float32)
        y = (0.05 / (x[:, 0] * x[:, 1] ** 0.7)).astype(np.float32)
        kw = dict(method="rff", gamma=0.5, standardize=True, log_target=True)
        svr.fit_many([(x, y)], **kw)  # warm (BLAS/thread pools)
        _, fit_us = timed(svr.fit_many, [(x, y)], **kw)
        emit("engine_scale_rff_fit", fit_us, f"n={n}")
        rff_rows.append({"n_samples": n, "fit_us": fit_us})

    time_ratio = rff_rows[-1]["fit_us"] / rff_rows[0]["fit_us"]
    n_ratio = rff_ns[-1] / rff_ns[0]
    rff_linearity = time_ratio / n_ratio
    scale_speedup = plan_rows[-1]["speedup"]
    emit(
        "engine_scale",
        plan_rows[-1]["fused_us"],
        f"B={plan_rows[-1]['n_workloads']}_scale_speedup={scale_speedup:.1f}x_"
        f"rff_linearity={rff_linearity:.2f}",
    )
    save_json(
        "engine_scale",
        {
            "plan": plan_rows,
            "scale_speedup": scale_speedup,
            "rff_fit": rff_rows,
            "rff_linearity": rff_linearity,
        },
    )
    return scale_speedup


if __name__ == "__main__":
    # PYTHONPATH=src python -m benchmarks.bench_engine
    print("name,us_per_call,derived")
    run()
    run_scale()
