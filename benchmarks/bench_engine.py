"""plan_many throughput: the batched PlanningEngine vs the sequential seed
path (one full characterization + Gram-predict per plan).

The realistic fleet scenario: a scheduler plans many workload *variants*
(objectives, deadlines, step budgets) drawn from a handful of workload
families. The seed path paid a full SVR fit per plan; the engine pays one
fit per family (memoized) and pushes every pending grid through one batched
``rbf_gram`` call. Acceptance: ≥3× on ≥8 workloads, with identical chosen
configurations.
"""

from __future__ import annotations

from benchmarks.common import emit, save_json, timed
from repro.configs.base import SHAPES
from repro.core.engine import Constraints, PlanningEngine, Workload
from repro.core.tpu_power import FleetTelemetry, fit_fleet_power

FAMILIES = [
    ("qwen1.5-110b", "train_4k"),
    ("gemma3-12b", "prefill_32k"),
    ("starcoder2-3b", "train_4k"),
    ("mamba2-130m", "train_4k"),
]


def _workloads():
    """16 planning requests over 4 characterization families."""
    out = []
    for arch, shape in FAMILIES:
        cell = SHAPES[shape]
        out.append(Workload(arch, cell))
        out.append(Workload(arch, cell, objective="edp"))
        out.append(Workload(arch, cell, n_steps=1000, objective="ed2p"))
        out.append(
            Workload(arch, cell, constraints=Constraints(max_frequency_ghz=0.95))
        )
    return out


def run():
    pm = fit_fleet_power(FleetTelemetry(seed=0))
    workloads = _workloads()

    # warm up jit caches outside the timed region — the batched objective
    # tensor compiles per batch size, so warm both the B=16 and B=1 shapes
    warm = PlanningEngine(pm, noise=0.01, seed=0)
    warm.plan_many(workloads)
    warm.clear_cache(analytic=False)
    warm.plan(workloads[0])

    seq_eng = PlanningEngine(pm, noise=0.01, seed=0)

    def sequential():
        plans = []
        for w in workloads:
            # the seed path re-characterized (re-FIT) every plan but kept
            # the analytic-terms memo; clearing it too would time
            # jax.eval_shape re-traces instead of fit/predict cost
            seq_eng.clear_cache(analytic=False)
            plans.append(seq_eng.plan(w))
        return plans

    seq_plans, seq_us = timed(sequential)

    batch_eng = PlanningEngine(pm, noise=0.01, seed=0)
    batch_plans, batch_us = timed(batch_eng.plan_many, workloads)

    seq_cfg = [(p.frequency_ghz, p.chips) for p in seq_plans]
    batch_cfg = [(p.frequency_ghz, p.chips) for p in batch_plans]
    assert seq_cfg == batch_cfg, "batched plans diverge from sequential plans"

    speedup = seq_us / batch_us
    emit(
        "engine_plan_many",
        batch_us,
        f"n={len(workloads)}_families={len(FAMILIES)}_"
        f"seq_us={seq_us:.0f}_speedup={speedup:.1f}x_parity=ok",
    )
    save_json(
        "engine",
        {
            "n_workloads": len(workloads),
            "n_families": len(FAMILIES),
            "sequential_us": seq_us,
            "batched_us": batch_us,
            "speedup": speedup,
            "plans": [p.__dict__ for p in batch_plans],
        },
    )
    return speedup


if __name__ == "__main__":
    # PYTHONPATH=src python -m benchmarks.bench_engine
    print("name,us_per_call,derived")
    run()
