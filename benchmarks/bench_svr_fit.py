"""Batched SVR fitting: ``svr.fit_many`` vs sequential ``fit`` on 8
workload families, plus the governor closed loop.

Acceptance (ISSUE 2): fit_many >= 3x over one-at-a-time fits on 8 engine
training sets with config-choice parity — the plans picked from batched
fits must equal the plans picked from sequential fits, (f, chips) exact.
The emitted ``experiments/bench/svr_fit.json`` also carries the
``core.evaluate`` governor comparison (quick grid) so the paper's
worst-case governor ratio rides in the bench artifact.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.core import evaluate, svr
from repro.core.engine import (
    ENGINE_FIT_KW,
    PlanningEngine,
    RooflineTerms,
    Workload,
)
from repro.core.node_sim import FREQ_GRID, MAX_CORES, Node
from repro.core.tpu_power import FleetTelemetry, fit_fleet_power

# 8 workload families spanning compute-, memory- and collective-bound mixes
FAMILY_TERMS = [
    RooflineTerms(
        compute_s=0.002 * (i + 1),
        memory_s=0.0008 * (8 - i),
        collective_s=0.0004 * (1 + i % 3),
        source="synthetic",
    )
    for i in range(8)
]

FIT_KW = ENGINE_FIT_KW  # bench fits exactly what the engine fits


def run():
    pm = fit_fleet_power(FleetTelemetry(seed=0))
    engine = PlanningEngine(pm, noise=0.01, seed=0)
    sets = [engine._training_set(t) for t in FAMILY_TERMS]

    # warm the jit caches (batched gram compiles per (B, n) shape)
    svr.fit_many(sets, **FIT_KW)
    [svr.fit(x, y, **FIT_KW) for x, y in sets]

    def med(fn, reps=5):
        times = []
        for _ in range(reps):
            _, us = timed(fn)
            times.append(us)
        return float(np.median(times))

    seq_us = med(lambda: [svr.fit(x, y, **FIT_KW) for x, y in sets])
    batch_us = med(lambda: svr.fit_many(sets, **FIT_KW))
    speedup = seq_us / batch_us

    # config-choice parity: plans from one-at-a-time fits == plans from one
    # batched fit_many characterization, (f, chips) exact
    workloads = [Workload("fam%d" % i, None, terms=t)
                 for i, t in enumerate(FAMILY_TERMS)]
    seq_eng = PlanningEngine(pm, noise=0.01, seed=0)
    seq_plans = [seq_eng.plan(w) for w in workloads]  # B=1 fits
    batch_eng = PlanningEngine(pm, noise=0.01, seed=0)
    batch_plans = batch_eng.plan_many(workloads)  # one B=8 fit_many
    seq_cfg = [(p.frequency_ghz, p.chips) for p in seq_plans]
    batch_cfg = [(p.frequency_ghz, p.chips) for p in batch_plans]
    assert seq_cfg == batch_cfg, "batched fits diverge from sequential fits"

    emit(
        "svr_fit_many",
        batch_us,
        f"n_families={len(sets)}_seq_us={seq_us:.0f}_"
        f"speedup={speedup:.1f}x_parity=ok",
    )

    # the governor closed loop (quick grid): paper's worst-case headline
    t0 = time.time()
    report = evaluate.compare_governors(
        Node(seed=42),
        char_freqs=FREQ_GRID[::2],
        char_cores=range(1, MAX_CORES + 1, 2),
        input_sizes=(1.0, 3.0, 5.0),
        governor_cores=(1, 8, 32),
    )
    emit(
        "governor_closed_loop",
        (time.time() - t0) * 1e6,
        f"worst_case={report.worst_case_ratio:.1f}x_"
        f"mean={report.mean_ratio:.1f}x_best={report.best_case_ratio:.2f}x",
    )

    save_json(
        "svr_fit",
        {
            "n_families": len(sets),
            "n_train_points": int(sets[0][0].shape[0]),
            "sequential_us": seq_us,
            "batched_us": batch_us,
            "speedup": speedup,
            "config_parity": seq_cfg == batch_cfg,
            "configs": batch_cfg,
            "worst_case_governor_ratio": report.worst_case_ratio,
            "governor_comparison": report.to_json(),
        },
    )
    return speedup


if __name__ == "__main__":
    # PYTHONPATH=src python -m benchmarks.bench_svr_fit
    print("name,us_per_call,derived")
    run()
