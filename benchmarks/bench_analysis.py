"""Self-timing for the repro-lint pass (``python -m repro.analysis``).

The pass runs at the top of EVERY ``scripts/verify.sh`` invocation, so
its wall time is part of the edit-test loop the same way the engine's
dispatch time is part of a scheduling round. This bench times the full
in-process sweep over ``src/``, ``benchmarks/`` and ``examples/`` and
records per-file cost plus the finding counts, so a rule whose visitor
goes quadratic (or a tree that doubles) shows up in the trajectory
before it shows up as a sluggish verify loop.

Stdlib-only by construction — the analysis subsystem imports no jax.
"""

import os

from benchmarks import common
from repro.analysis import Baseline, analyze_paths

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
PATHS = ("src", "benchmarks", "examples")


def run(quick: bool = False):
    repeats = 1 if quick else 3
    # warm once (first parse pays os.walk + file reads into page cache)
    analyze_paths(PATHS, root=REPO)
    best_us = None
    result = None
    for _ in range(repeats):
        result, us = common.timed(analyze_paths, PATHS, root=REPO)
        best_us = us if best_us is None else min(best_us, us)
    baseline = Baseline.load(os.path.join(REPO, "analysis_baseline.json"))
    new, baselined = baseline.split(result.findings)

    per_file_us = best_us / max(result.n_files, 1)
    common.emit(
        "analysis_full_pass",
        best_us,
        f"{result.n_files} files, {per_file_us:.0f} us/file",
    )
    common.save_json(
        "analysis",
        {
            "pass_us": best_us,
            "us_per_file": per_file_us,
            "n_files": result.n_files,
            "n_findings": len(result.findings),
            "n_new": len(new),
            "n_baselined": len(baselined),
            "n_suppressed": result.n_suppressed,
        },
    )


if __name__ == "__main__":
    run()
