"""§Roofline: three-term roofline per (arch × shape × mesh) from the dry-run.

  compute term    = HLO_FLOPs / peak_FLOP/s            (per chip, seconds)
  memory term     = HLO_bytes / HBM_bw
  collective term = collective_bytes / link_bw
  (all per-device quantities — the compiled HLO is the per-device program)

plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the usefulness
ratio MODEL_FLOPS / (HLO_FLOPs × chips).
"""

from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit, save_json
from repro.configs import ARCHS
from repro.configs.base import SHAPES
from repro.core.tpu_power import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def _param_counts(arch_id):
    """(total, active) parameter counts via eval_shape (no allocation)."""
    import jax

    arch = ARCHS[arch_id]
    abs_params = jax.eval_shape(lambda: arch.init(jax.random.PRNGKey(0), arch.full))
    flat = jax.tree_util.tree_flatten_with_path(abs_params)[0]
    total = 0
    active = 0.0
    moe = getattr(arch.full, "moe_cfg", None)
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        total += n
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if moe is not None and "experts" in keys:
            active += n * (moe.top_k / moe.n_experts)
        else:
            active += n
    return total, int(active)


def model_flops(arch_id, shape_name):
    cell = SHAPES[shape_name]
    total, active = _param_counts(arch_id)
    if cell.kind == "train":
        return 6.0 * active * cell.seq * cell.batch
    if cell.kind == "prefill":
        return 2.0 * active * cell.seq * cell.batch
    return 2.0 * active * cell.batch  # decode: one token per sequence


def fix_note(dom, rec):
    h = rec["hlo"]
    cols = h.get("collectives", {})
    biggest = max(cols, key=cols.get) if cols else "none"
    return {
        "compute": "increase arithmetic intensity (larger per-chip tiles / fewer remat recomputes)",
        "memory": "fuse/streamline HBM traffic: bigger attention blocks, fewer reshapes, bf16 opt-state reads",
        "collective": f"restructure sharding to shrink {biggest} volume (overlap with compute where irreducible)",
    }[dom]


def rows(dryrun_dir=DRYRUN_DIR):
    out = []
    for fname in sorted(os.listdir(dryrun_dir)):
        if not fname.endswith(".json"):
            continue
        with open(os.path.join(dryrun_dir, fname)) as f:
            rec = json.load(f)
        if not rec.get("ok"):
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"], "ok": False,
                        "error": rec.get("error", "?")})
            continue
        h = rec["hlo"]
        chips = rec["n_devices"]
        t_comp = h["flops_per_device"] / PEAK_FLOPS_BF16
        t_mem = h["memory_bytes_per_device"] / HBM_BW
        t_coll = h["collective_bytes_per_device"] / ICI_BW
        dom = max(
            (("compute", t_comp), ("memory", t_mem), ("collective", t_coll)),
            key=lambda kv: kv[1],
        )[0]
        mf = model_flops(rec["arch"], rec["shape"])
        hlo_total = h["flops_per_device"] * chips
        out.append(
            {
                "arch": rec["arch"],
                "shape": rec["shape"],
                "mesh": rec["mesh"],
                "ok": True,
                "chips": chips,
                "compute_s": t_comp,
                "memory_s": t_mem,
                "collective_s": t_coll,
                "dominant": dom,
                "model_flops": mf,
                "hlo_flops_total": hlo_total,
                "useful_ratio": mf / hlo_total if hlo_total else 0.0,
                "roofline_fraction": t_comp / max(t_comp, t_mem, t_coll),
                "fix": fix_note(dom, rec),
                "collectives": h.get("collectives", {}),
                "temp_bytes": rec["memory_analysis"].get("temp_size_in_bytes", 0),
                "arg_bytes": rec["memory_analysis"].get("argument_size_in_bytes", 0),
            }
        )
    return out


def run():
    table = rows()
    ok_rows = [r for r in table if r.get("ok")]
    for r in ok_rows:
        if r["mesh"] != "pod":
            continue
        emit(
            f"roofline_{r['arch']}_{r['shape']}",
            0.0,
            f"comp={r['compute_s']:.3f}s_mem={r['memory_s']:.3f}s_"
            f"coll={r['collective_s']:.3f}s_dom={r['dominant']}"
            f"_useful={r['useful_ratio']:.2f}_frac={r['roofline_fraction']:.2f}",
        )
    n_bad = len(table) - len(ok_rows)
    emit("roofline_summary", 0.0, f"cells_ok={len(ok_rows)}_failed={n_bad}")
    save_json("roofline", table)
    return table
