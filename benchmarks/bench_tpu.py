"""Space-generic TPU planning vs the checked-in seed plans.

``experiments/bench/tpu_planner.json`` holds the model-zoo plans the
legacy (implicit-TPU-grid) engine produced. This bench re-plans the same
workloads through an engine built on an EXPLICIT ``tpu_space()`` — the
device-generic ``ConfigSpace`` path every layer now shares — and asserts
config parity per workload: same chips, frequency, pods and mesh. A
mismatch means the generic axis moved a planning decision, which the
ConfigSpace refactor promises never to do.
"""

from __future__ import annotations

from benchmarks.common import emit, save_json, timed
from repro.configs.base import SHAPES
from repro.core.engine import PlanningEngine, Workload, tpu_space
from repro.core.tpu_power import FleetTelemetry, fit_fleet_power

# the tpu_planner seed's workload list, verbatim
WORKLOADS = [
    ("qwen1.5-110b", "train_4k"),
    ("phi3.5-moe-42b-a6.6b", "train_4k"),
    ("gemma3-12b", "prefill_32k"),
    ("gemma3-12b", "decode_32k"),
    ("starcoder2-3b", "train_4k"),
    ("zamba2-7b", "long_500k"),
    ("mamba2-130m", "train_4k"),
]

SEED_PATH = "experiments/bench/tpu_planner.json"


def _load_seed():
    import json
    import os

    if not os.path.exists(SEED_PATH):
        return None
    with open(SEED_PATH) as f:
        return json.load(f)


def run():
    engine = PlanningEngine(
        fit_fleet_power(FleetTelemetry(seed=0)),
        space=tpu_space(),
        noise=0.01,
        seed=0,
    )
    requests = [Workload(arch_id, SHAPES[shape]) for arch_id, shape in WORKLOADS]
    plans, us = timed(engine.plan_many, requests)

    seed = _load_seed()
    out = {"space": engine.space.name, "plans": {}, "seed_parity": 1.0}
    for (arch_id, shape), plan in zip(WORKLOADS, plans):
        key = f"{arch_id}/{shape}"
        config = dict(
            chips=int(plan.chips),
            pods=int(plan.pods),
            frequency_ghz=float(plan.frequency_ghz),
            mesh=list(plan.mesh),
        )
        out["plans"][key] = config
        if seed is not None and key in seed:
            want = {
                k: (list(seed[key][k]) if k == "mesh" else seed[key][k])
                for k in config
            }
            if config != want:
                raise AssertionError(
                    f"bench_tpu: space-generic plan for {key} diverged from "
                    f"the seed: got {config}, seed has {want}"
                )
        emit(
            f"tpu_space_plan_{arch_id}_{shape}",
            us / len(plans),
            f"{config['chips']}chips@{config['frequency_ghz']:.2f}GHz_"
            f"pods={config['pods']}_seed_parity=1",
        )
    out["plan_us_per_workload"] = us / len(plans)
    emit("tpu_space_plan_many_total", us, f"n={len(plans)}_space={engine.space.name}")
    save_json("bench_tpu", out)
    return out
