"""Paper-reproduction benchmarks: one function per paper table/figure.

  fig1_power_fit         — §3.3 / Eq. 9 / Fig. 1: stress sweep -> OLS fit
  table1_svr_cv          — §3.4 / Table 1: full characterization + 10-fold CV
  figs6_9_energy_surface — §4.1 / Figs. 6-9: modeled vs measured energy
  tables2_5_vs_ondemand  — §4.2 / Tables 2-5 + Fig. 10: proposed vs Ondemand
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.core import characterize, energy, governor, power, svr
from repro.core.node_sim import FREQ_GRID, INPUT_SIZES, PROFILES, Node

APPS = ("blackscholes", "fluidanimate", "raytrace", "swaptions")


def fig1_power_fit():
    node = Node(seed=42)
    (f, p, s, w), us = timed(node.stress_grid)
    pm = power.fit_power_model(f, p, s, w)
    rep = power.fit_report(pm, f, p, s, w)
    derived = (
        f"c=({rep['c1']:.3f};{rep['c2']:.3f};{rep['c3']:.2f};{rep['c4']:.2f})"
        f"_ape={rep['ape']:.4f}_rmse={rep['rmse_watts']:.2f}W"
        f"_paper=(0.29;0.97;198.59;9.18)_ape0.0075_rmse2.38W"
    )
    emit("fig1_power_fit", us, derived)
    save_json("fig1_power_fit", rep)
    return pm


def table1_svr_cv(full: bool = True):
    node = Node(seed=42)
    rows = {}
    for app in APPS:
        ch = characterize.characterize(
            characterize.NodeSampler(node, app),
            app,
            freqs=FREQ_GRID if full else FREQ_GRID[::2],
            cores=range(1, 33) if full else range(1, 33, 2),
            input_sizes=INPUT_SIZES if full else (1.0, 3.0, 5.0),
        )
        (res, us) = timed(ch.cross_validate, k=10)
        mae, pae = res
        rows[app] = {"mae": mae, "pae": pae, "n": len(ch.times)}
        emit(f"table1_svr_cv_{app}", us, f"mae={mae:.3f}_pae={pae:.4f}")
    save_json("table1_svr_cv", rows)
    return rows


def figs6_9_energy_surface(pm: power.PowerModel):
    """Modeled vs measured energy over (f, p) at mid input (N=3)."""
    node = Node(seed=42)
    out = {}
    for app in APPS:
        ch = characterize.characterize(
            characterize.NodeSampler(node, app),
            app,
            freqs=FREQ_GRID[::2],
            cores=range(1, 33, 4),
            input_sizes=(3.0,),
        )
        perf = ch.fit_svr()
        F, P, T, W, E = energy.energy_grid(
            pm, perf, frequencies=FREQ_GRID[::2], cores=range(1, 33, 4), input_size=3
        )
        E_meas = np.array(
            [
                [node.run_fixed(app, float(f), int(p), 3.0).energy_j for p in range(1, 33, 4)]
                for f in FREQ_GRID[::2]
            ]
        )
        err = float(np.mean(np.abs(E - E_meas) / E_meas))
        out[app] = {"model_vs_measured_ape": err}
        emit(f"figs6_9_energy_{app}", 0.0, f"model_vs_measured_ape={err:.4f}")
    save_json("figs6_9_energy_surface", out)
    return out


def tables2_5_vs_ondemand(pm: power.PowerModel, full: bool = True):
    node = Node(seed=42)
    table = {}
    core_set = (1, 2, 4, 8, 12, 16, 20, 24, 28, 30, 32)
    for app in APPS:
        ch = characterize.characterize(
            characterize.NodeSampler(node, app),
            app,
            freqs=FREQ_GRID if full else FREQ_GRID[::2],
            cores=range(1, 33) if full else range(1, 33, 2),
            input_sizes=INPUT_SIZES,
        )
        perf = ch.fit_svr()
        rows = []
        for n in INPUT_SIZES:
            cfg = energy.minimize_energy(
                pm, perf, frequencies=FREQ_GRID, cores=range(1, 33), input_size=n
            )
            proposed = node.run_fixed(app, cfg.frequency_ghz, cfg.cores, n)
            od = {}
            for c in core_set:
                r = node.run_governor(app, governor.OndemandGovernor(), c, n)
                od[c] = {
                    "energy_kj": r.energy_j / 1e3,
                    "mean_f": r.mean_freq_ghz,
                }
            best_c = min(od, key=lambda c: od[c]["energy_kj"])
            worst_c = max(od, key=lambda c: od[c]["energy_kj"])
            save_min = 100 * (od[best_c]["energy_kj"] * 1e3 - proposed.energy_j) / proposed.energy_j
            save_max = 100 * (od[worst_c]["energy_kj"] * 1e3 - proposed.energy_j) / proposed.energy_j
            rows.append(
                {
                    "input": n,
                    "proposed": {
                        "f": cfg.frequency_ghz,
                        "cores": cfg.cores,
                        "energy_kj": proposed.energy_j / 1e3,
                    },
                    "ondemand_min": {"cores": best_c, **od[best_c]},
                    "ondemand_max": {"cores": worst_c, **od[worst_c]},
                    "save_min_pct": save_min,
                    "save_max_pct": save_max,
                    "normalized": {
                        c: od[c]["energy_kj"] * 1e3 / proposed.energy_j for c in od
                    },  # Fig. 10
                }
            )
            emit(
                f"tables2_5_{app}_N{int(n)}",
                0.0,
                f"proposed={cfg.frequency_ghz:.1f}GHz/{cfg.cores}c/"
                f"{proposed.energy_j/1e3:.2f}kJ_saveMin={save_min:.1f}%"
                f"_saveMax={save_max:.1f}%",
            )
        table[app] = rows
    all_rows = [r for rows in table.values() for r in rows]
    avg_min = float(np.mean([r["save_min_pct"] for r in all_rows]))
    avg_max = float(np.mean([r["save_max_pct"] for r in all_rows]))
    emit(
        "tables2_5_summary",
        0.0,
        f"avg_save_vs_best={avg_min:.1f}%_avg_save_vs_worst={avg_max:.0f}%"
        f"_paper=6%_790%",
    )
    table["summary"] = {"avg_save_min_pct": avg_min, "avg_save_max_pct": avg_max}
    save_json("tables2_5_vs_ondemand", table)
    return table


def run(full: bool = True):
    pm = fig1_power_fit()
    table1_svr_cv(full=full)
    figs6_9_energy_surface(pm)
    tables2_5_vs_ondemand(pm, full=full)
