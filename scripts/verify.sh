#!/usr/bin/env bash
# The repo's verification gate, pinned in one place (tests/test_docs.py
# asserts this script and the commands it runs stay in sync with the
# documented tier-1 command):
#
#   scripts/verify.sh          # tier-1: PYTHONPATH=src python -m pytest -x -q
#   scripts/verify.sh --fast   # sub-minute loop: ... -m "not slow"
#
# Both modes run first (stdlib-only, sub-second):
#   * repro-lint — python -m repro.analysis over src/ benchmarks/
#     examples/ against the committed baseline; any NEW contract
#     violation fails the gate before the tests even start.
#   * the trajectory perf gate — scripts/check_trajectory.py fails if
#     the latest benchmark trajectory entry regressed >20% against the
#     median of its prior comparable entries (plus absolute ceilings,
#     e.g. service.overhead_ratio <= 1.15).
#
# The fast loop includes the service-layer gates: replay determinism
# (tests/test_service.py) and the early/mid/late crash-recovery slice +
# single-fault recovery (tests/test_service_recovery.py) are unmarked,
# so `--fast` covers them; the exhaustive kill-at-every-batch sweeps
# ride the slow tier. It also covers the heterogeneous-pool path: the
# mixed CPU+TPU scheduling/journal tests in tests/test_config_space.py
# (test_mixed_pool_scenario et al.) are unmarked by design.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m repro.analysis src benchmarks examples --baseline analysis_baseline.json
python scripts/check_trajectory.py

if [[ "${1:-}" == "--fast" ]]; then
    exec python -m pytest -x -q -m "not slow"
fi
exec python -m pytest -x -q
