#!/usr/bin/env bash
# The repo's verification gate, pinned in one place (tests/test_docs.py
# asserts this script and the commands it runs stay in sync with the
# documented tier-1 command):
#
#   scripts/verify.sh          # tier-1: PYTHONPATH=src python -m pytest -x -q
#   scripts/verify.sh --fast   # sub-minute loop: ... -m "not slow"
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--fast" ]]; then
    exec python -m pytest -x -q -m "not slow"
fi
exec python -m pytest -x -q
