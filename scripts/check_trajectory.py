"""Perf-regression gate over ``experiments/bench/trajectory.json``.

``benchmarks/run.py --append-trajectory`` appends one dated entry per
run; this script (stdlib-only, run by ``scripts/verify.sh``) fails when
the LATEST entry's fleet metrics regress more than ``--threshold``
(default 20%) against the history:

* ``fleet.speedup`` (batched round vs sequential; higher is better)
* ``fleet.lookahead_overhead_ratio`` (horizon-aware round cost vs plain;
  lower is better)
* ``engine_scale.scale_speedup`` (fused Pallas sweep vs the exact
  batched path at the largest B; higher is better)
* ``obs.overhead_ratio`` / ``obs.null_overhead_ratio`` (flight-recorder
  cost on the scheduling round, recording and default-off)
* ``service.overhead_ratio`` (event-driven ``SchedulerService`` run vs
  the lockstep ``run()`` on the same trace; lower is better)

The reference is the **median of the prior comparable entries** (same
``quick`` flag), not the best-ever entry: single-shot container timings
in the shipped history swing ±25% run to run, so a best-ever ratchet
monotonically tightens until a healthy run fails. The median tracks the
typical machine instead and still catches a real 20% cliff.

A metric may additionally carry an **absolute ceiling** — a design
budget, not a trend (the obs overhead contract: recording ≤ 3% of a
round, the default-off null path ≤ 0.5%). Ceilings gate the latest
entry whenever the metric is present, even on thin history: a budget
does not need priors to be violated.

Exit codes: 0 = ok (or not enough history to judge), 1 = regression,
2 = unreadable trajectory file.
"""

from __future__ import annotations

import argparse
import json
import statistics
import sys
from typing import List, Optional, Sequence, Tuple

DEFAULT_PATH = "experiments/bench/trajectory.json"

# (results section, metric key, direction[, ceiling]): +1 = higher is
# better; an optional 4th element is an absolute ceiling (lower-is-better
# metrics only) enforced on the latest entry regardless of history depth
METRICS: Tuple[Tuple, ...] = (
    ("fleet", "speedup", +1),
    ("fleet", "lookahead_overhead_ratio", -1),
    ("engine_scale", "scale_speedup", +1),
    # space-generic TPU planning cost per model-zoo workload; the bench
    # itself hard-asserts seed-config parity, this only trends the timing
    ("bench_tpu", "plan_us_per_workload", -1),
    ("obs", "overhead_ratio", -1, 1.03),
    ("obs", "null_overhead_ratio", -1, 1.005),
    ("service", "overhead_ratio", -1, 1.15),
)


def section_metric(entry: dict, section: str, key: str) -> Optional[float]:
    value = entry.get("results", {}).get(section, {}).get(key)
    return float(value) if isinstance(value, (int, float)) else None


def check(trajectory: List[dict], threshold: float) -> List[str]:
    """Regression messages for the latest entry ([] = gate passes)."""
    if not trajectory:
        return []
    latest = trajectory[-1]
    problems = []
    # absolute ceilings first: design budgets bind without any history
    for spec in METRICS:
        ceiling = spec[3] if len(spec) > 3 else None
        current = section_metric(latest, spec[0], spec[1])
        if ceiling is not None and current is not None and current > ceiling:
            problems.append(
                f"{spec[0]}.{spec[1]} exceeds its absolute budget: latest "
                f"{current:.4f} > ceiling {ceiling}"
            )
    if len(trajectory) < 3:
        return problems  # one prior entry is not a trend — don't gate on noise
    priors = [e for e in trajectory[:-1] if e.get("quick") == latest.get("quick")]
    for section, key, direction in (spec[:3] for spec in METRICS):
        current = section_metric(latest, section, key)
        history = [
            m
            for m in (section_metric(e, section, key) for e in priors)
            if m is not None
        ]
        if current is None or len(history) < 2:
            continue
        reference = statistics.median(history)
        if direction > 0:
            regressed = current < (1.0 - threshold) * reference
        else:
            regressed = current > (1.0 + threshold) * reference
        if regressed:
            problems.append(
                f"{section}.{key} regressed >{threshold:.0%}: latest "
                f"{current:.3f} vs median-of-{len(history)}-priors "
                f"{reference:.3f}"
            )
    return problems


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python scripts/check_trajectory.py",
        description="fail when the latest benchmark trajectory entry "
        "regresses against the median of its prior comparable entries",
    )
    parser.add_argument(
        "--path",
        default=DEFAULT_PATH,
        help="trajectory file (default: %(default)s)",
    )
    parser.add_argument(
        "--threshold",
        type=float,
        default=0.20,
        help="allowed relative regression (default: %(default)s)",
    )
    args = parser.parse_args(argv)

    try:
        with open(args.path, encoding="utf-8") as f:
            trajectory = json.load(f)
    except FileNotFoundError:
        print(f"trajectory gate: no history at {args.path} — nothing to check")
        return 0
    except (OSError, json.JSONDecodeError) as e:
        print(f"trajectory gate: cannot read {args.path}: {e}", file=sys.stderr)
        return 2
    if not isinstance(trajectory, list):
        print(f"trajectory gate: {args.path} is not a list", file=sys.stderr)
        return 2

    problems = check(trajectory, args.threshold)
    if problems:
        for p in problems:
            print(f"FAIL: {p}", file=sys.stderr)
        print(
            "(re-run `python -m benchmarks.run --append-trajectory` on a "
            "quiet machine, or fix the regression)",
            file=sys.stderr,
        )
        return 1
    print(
        f"trajectory gate: ok ({len(trajectory)} entr"
        f"{'y' if len(trajectory) == 1 else 'ies'}, threshold "
        f"{args.threshold:.0%})"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
